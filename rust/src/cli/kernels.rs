//! `besa kernel-bench` — the microkernel roofline driver.
//!
//! Times every kernel family in [`crate::kernel`] at representative
//! shapes — decode matvec, prefill GEMM, backward GEMMs, f64 matmul,
//! CSR SpMM across sparsities, fused-dequant SpMM, attention score /
//! weighted-sum rows — running the scalar reference and the micro kernel
//! back to back, and writes a roofline-style record (GFLOP/s per kernel
//! per shape, scalar vs micro, speedup) to `BENCH_kernels.json`.
//!
//! Before timing a shape the driver asserts the two implementations
//! agree *bitwise* on that exact input — the bench refuses to report a
//! speedup for a kernel that broke parity. `--smoke` shrinks shapes and
//! the time budget to CI scale; `--json <path>` overrides the output
//! path (`BESA_BENCH_SECS` scales the per-case budget as usual).

use std::path::PathBuf;

use anyhow::{ensure, Context, Result};

use crate::kernel::{attn, gemm, spmm};
use crate::quant::QuantSpec;
use crate::sparse::csr::{Csr, QuantCsr};
use crate::tensor::Tensor;
use crate::util::args::Args;
use crate::util::bench::Bench;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// Family tags every run must cover — the CI smoke gate (and the test
/// below) checks the emitted JSON contains one record per entry.
pub const FAMILIES: [&str; 9] = [
    "matvec",
    "gemm_nt",
    "gemm_nn",
    "gemm_tn",
    "matmul_f64",
    "spmm_csr",
    "spmm_quant",
    "attn_dots",
    "attn_wsum",
];

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed(seed);
    (0..n).map(|_| rng.normal_f32()).collect()
}

fn randv64(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::seed(seed);
    (0..n).map(|_| rng.normal_f32() as f64).collect()
}

/// Dense `[rows, cols]` tensor with an exact-zero fraction of `sparsity`.
fn random_sparse(rows: usize, cols: usize, sparsity: f64, seed: u64) -> Tensor {
    let mut rng = Rng::seed(seed);
    let data: Vec<f32> = (0..rows * cols)
        .map(|_| if rng.f64() < sparsity { 0.0 } else { rng.normal_f32() })
        .collect();
    Tensor::from_f32(&[rows, cols], data)
}

/// One scalar-vs-micro roofline record.
fn record(family: &str, shape: &str, flops: f64, scalar_ns: f64, micro_ns: f64) -> Json {
    json::obj(vec![
        ("family", json::s(family)),
        ("shape", json::s(shape)),
        ("flops", json::num(flops)),
        (
            "scalar",
            json::obj(vec![
                ("mean_ns", json::num(scalar_ns)),
                ("gflops", json::num(flops / scalar_ns)),
            ]),
        ),
        (
            "micro",
            json::obj(vec![
                ("mean_ns", json::num(micro_ns)),
                ("gflops", json::num(flops / micro_ns)),
            ]),
        ),
        ("speedup", json::num(scalar_ns / micro_ns)),
        ("parity", json::s("bitwise")),
    ])
}

pub fn cmd_kernel_bench(args: &Args) -> Result<()> {
    let smoke = args.has("smoke");
    let json_path = PathBuf::from(args.str_or("json", "BENCH_kernels.json"));
    let mut b = Bench::new("kernel_bench");
    if smoke {
        b = b.warmup(1).budget_secs(0.02);
    }
    let mut records: Vec<Json> = Vec::new();

    // ---- decode matvec (m=1 linear, the cached-decode projection) --------
    let matvec_shapes: &[(usize, usize)] =
        if smoke { &[(17, 9)] } else { &[(512, 512), (512, 2048)] };
    for &(k, n) in matvec_shapes {
        let x = randv(k, 11);
        let w = randv(k * n, 12);
        let mut ys = vec![0.0f32; n];
        let mut ym = vec![0.0f32; n];
        gemm::matvec_scalar_into(&x, &w, k, n, &mut ys);
        gemm::matvec_micro_into(&x, &w, k, n, &mut ym);
        ensure!(ys == ym, "matvec parity broke at k={k} n={n}");
        let flops = 2.0 * (k * n) as f64;
        let id = format!("matvec k={k} n={n}");
        let s_ns = b
            .run(&format!("{id} scalar"), || {
                gemm::matvec_scalar_into(&x, &w, k, n, &mut ys);
                ys[0]
            })
            .mean_ns;
        let m_ns = b
            .run(&format!("{id} micro"), || {
                gemm::matvec_micro_into(&x, &w, k, n, &mut ym);
                ym[0]
            })
            .mean_ns;
        records.push(record("matvec", &format!("k={k} n={n}"), flops, s_ns, m_ns));
    }

    // ---- prefill GEMM (y = x @ w^T, the forward linear) ------------------
    let nt_shapes: &[(usize, usize, usize)] =
        if smoke { &[(5, 17, 9)] } else { &[(128, 512, 512), (128, 512, 2048)] };
    for &(m, k, n) in nt_shapes {
        let x = randv(m * k, 21);
        let w = randv(n * k, 22);
        ensure!(
            gemm::mm_nt_scalar(&x, &w, m, k, n) == gemm::mm_nt_micro(&x, &w, m, k, n),
            "mm_nt parity broke at m={m} k={k} n={n}"
        );
        let flops = 2.0 * (m * k * n) as f64;
        let id = format!("gemm_nt m={m} k={k} n={n}");
        let s_ns = b.run(&format!("{id} scalar"), || gemm::mm_nt_scalar(&x, &w, m, k, n)).mean_ns;
        let m_ns = b.run(&format!("{id} micro"), || gemm::mm_nt_micro(&x, &w, m, k, n)).mean_ns;
        records.push(record("gemm_nt", &format!("m={m} k={k} n={n}"), flops, s_ns, m_ns));
    }

    // ---- backward GEMMs (dx = g @ w, gw = g^T @ x) -----------------------
    let bwd_shapes: &[(usize, usize, usize)] =
        if smoke { &[(5, 9, 17)] } else { &[(128, 512, 512)] };
    for &(m, n, k) in bwd_shapes {
        let g = randv(m * n, 31);
        let w = randv(n * k, 32);
        let x = randv(m * k, 33);
        ensure!(
            gemm::mm_nn_scalar(&g, &w, m, n, k) == gemm::mm_nn_micro(&g, &w, m, n, k),
            "mm_nn parity broke at m={m} n={n} k={k}"
        );
        ensure!(
            gemm::mm_tn_scalar(&g, &x, m, n, k) == gemm::mm_tn_micro(&g, &x, m, n, k),
            "mm_tn parity broke at m={m} n={n} k={k}"
        );
        let flops = 2.0 * (m * n * k) as f64;
        let shape = format!("m={m} n={n} k={k}");
        let nn = format!("gemm_nn {shape}");
        let s_ns = b.run(&format!("{nn} scalar"), || gemm::mm_nn_scalar(&g, &w, m, n, k)).mean_ns;
        let m_ns = b.run(&format!("{nn} micro"), || gemm::mm_nn_micro(&g, &w, m, n, k)).mean_ns;
        records.push(record("gemm_nn", &shape, flops, s_ns, m_ns));
        let tn = format!("gemm_tn {shape}");
        let s_ns = b.run(&format!("{tn} scalar"), || gemm::mm_tn_scalar(&g, &x, m, n, k)).mean_ns;
        let m_ns = b.run(&format!("{tn} micro"), || gemm::mm_tn_micro(&g, &x, m, n, k)).mean_ns;
        records.push(record("gemm_tn", &shape, flops, s_ns, m_ns));
    }

    // ---- f64 matmul (linalg::Mat, probe/eigensolver shapes) --------------
    let f64_shapes: &[(usize, usize, usize)] = if smoke { &[(5, 7, 6)] } else { &[(96, 96, 96)] };
    for &(m, k, n) in f64_shapes {
        let a = randv64(m * k, 41);
        let c = randv64(k * n, 42);
        ensure!(
            gemm::matmul_f64_scalar(&a, &c, m, k, n) == gemm::matmul_f64_micro(&a, &c, m, k, n),
            "matmul_f64 parity broke at m={m} k={k} n={n}"
        );
        let flops = 2.0 * (m * k * n) as f64;
        let id = format!("matmul_f64 m={m} k={k} n={n}");
        let s_ns =
            b.run(&format!("{id} scalar"), || gemm::matmul_f64_scalar(&a, &c, m, k, n)).mean_ns;
        let m_ns =
            b.run(&format!("{id} micro"), || gemm::matmul_f64_micro(&a, &c, m, k, n)).mean_ns;
        records.push(record("matmul_f64", &format!("m={m} k={k} n={n}"), flops, s_ns, m_ns));
    }

    // ---- CSR SpMM across sparsities + fused-dequant SpMM -----------------
    let (rows, cols, t) = if smoke { (24, 16, 5) } else { (512, 512, 64) };
    for (si, &sparsity) in [0.5f64, 0.7, 0.9].iter().enumerate() {
        let csr = Csr::from_dense(&random_sparse(rows, cols, sparsity, 50 + si as u64));
        let xt = randv(cols * t, 60 + si as u64);
        let value = |kk: usize| csr.values[kk];
        let mut ys = vec![0.0f32; rows * t];
        let mut ym = vec![0.0f32; rows * t];
        spmm::spmm_rows_scalar(&csr.row_ptr, &csr.col_idx, value, &xt, t, 0, rows, &mut ys);
        spmm::spmm_rows_micro(&csr.row_ptr, &csr.col_idx, value, &xt, t, 0, rows, &mut ym);
        ensure!(ys == ym, "spmm parity broke at sparsity={sparsity}");
        let flops = 2.0 * (csr.nnz() * t) as f64;
        let shape = format!("rows={rows} cols={cols} t={t} sparsity={sparsity}");
        let s_ns = b
            .run(&format!("spmm_csr {shape} scalar"), || {
                ys.fill(0.0);
                spmm::spmm_rows_scalar(&csr.row_ptr, &csr.col_idx, value, &xt, t, 0, rows, &mut ys);
                ys[0]
            })
            .mean_ns;
        let m_ns = b
            .run(&format!("spmm_csr {shape} micro"), || {
                ym.fill(0.0);
                spmm::spmm_rows_micro(&csr.row_ptr, &csr.col_idx, value, &xt, t, 0, rows, &mut ym);
                ym[0]
            })
            .mean_ns;
        records.push(record("spmm_csr", &shape, flops, s_ns, m_ns));
    }
    {
        let sparsity = 0.7f64;
        let q =
            QuantCsr::from_dense(&random_sparse(rows, cols, sparsity, 70), QuantSpec::default());
        let xt = randv(cols * t, 71);
        let value = |kk: usize| q.value(kk);
        let mut ys = vec![0.0f32; rows * t];
        let mut ym = vec![0.0f32; rows * t];
        spmm::spmm_rows_scalar(&q.row_ptr, &q.col_idx, value, &xt, t, 0, rows, &mut ys);
        spmm::spmm_rows_micro(&q.row_ptr, &q.col_idx, value, &xt, t, 0, rows, &mut ym);
        ensure!(ys == ym, "quant spmm parity broke at sparsity={sparsity}");
        let flops = 2.0 * (q.nnz() * t) as f64;
        let shape = format!("rows={rows} cols={cols} t={t} sparsity={sparsity}");
        let s_ns = b
            .run(&format!("spmm_quant {shape} scalar"), || {
                ys.fill(0.0);
                spmm::spmm_rows_scalar(&q.row_ptr, &q.col_idx, value, &xt, t, 0, rows, &mut ys);
                ys[0]
            })
            .mean_ns;
        let m_ns = b
            .run(&format!("spmm_quant {shape} micro"), || {
                ym.fill(0.0);
                spmm::spmm_rows_micro(&q.row_ptr, &q.col_idx, value, &xt, t, 0, rows, &mut ym);
                ym[0]
            })
            .mean_ns;
        records.push(record("spmm_quant", &shape, flops, s_ns, m_ns));
    }

    // ---- attention score + weighted-sum rows -----------------------------
    let (nkeys, dh) = if smoke { (9, 8) } else { (512, 64) };
    {
        let q = randv(dh, 81);
        let kmat = randv(nkeys * dh, 82);
        let p = randv(nkeys, 83);
        let mut ys = vec![0.0f32; nkeys];
        let mut ym = vec![0.0f32; nkeys];
        attn::dots_scalar(&q, &kmat, dh, 0, nkeys, &mut ys);
        attn::dots_micro(&q, &kmat, dh, 0, nkeys, &mut ym);
        ensure!(ys == ym, "attn dots parity broke at keys={nkeys} dh={dh}");
        let flops = 2.0 * (nkeys * dh) as f64;
        let shape = format!("keys={nkeys} dh={dh}");
        let s_ns = b
            .run(&format!("attn_dots {shape} scalar"), || {
                attn::dots_scalar(&q, &kmat, dh, 0, nkeys, &mut ys);
                ys[0]
            })
            .mean_ns;
        let m_ns = b
            .run(&format!("attn_dots {shape} micro"), || {
                attn::dots_micro(&q, &kmat, dh, 0, nkeys, &mut ym);
                ym[0]
            })
            .mean_ns;
        records.push(record("attn_dots", &shape, flops, s_ns, m_ns));

        let mut os = vec![0.0f32; dh];
        let mut om = vec![0.0f32; dh];
        attn::wsum_scalar(&mut os, &p, &kmat, dh, 0);
        attn::wsum_micro(&mut om, &p, &kmat, dh, 0);
        ensure!(os == om, "attn wsum parity broke at keys={nkeys} dh={dh}");
        let s_ns = b
            .run(&format!("attn_wsum {shape} scalar"), || {
                os.fill(0.0);
                attn::wsum_scalar(&mut os, &p, &kmat, dh, 0);
                os[0]
            })
            .mean_ns;
        let m_ns = b
            .run(&format!("attn_wsum {shape} micro"), || {
                om.fill(0.0);
                attn::wsum_micro(&mut om, &p, &kmat, dh, 0);
                om[0]
            })
            .mean_ns;
        records.push(record("attn_wsum", &shape, flops, s_ns, m_ns));
    }

    b.report();

    let payload = json::obj(vec![
        ("bench", json::s("kernel_bench")),
        ("mode_default", json::s("micro")),
        ("smoke", Json::Bool(smoke)),
        (
            "provenance",
            json::s(&format!(
                "besa kernel-bench{} ({}-{})",
                if smoke { " --smoke" } else { "" },
                std::env::consts::ARCH,
                std::env::consts::OS,
            )),
        ),
        ("kernels", Json::Arr(records)),
    ]);
    std::fs::write(&json_path, payload.to_string_pretty() + "\n")
        .with_context(|| format!("writing {}", json_path.display()))?;
    println!("wrote {}", json_path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The smoke sweep must verify parity on every shape, cover every
    /// family and emit parseable JSON — the same contract the CI
    /// kernel-bench job checks against the binary.
    #[test]
    fn smoke_sweep_covers_every_family() {
        let path = std::env::temp_dir().join("besa_kernel_bench_test.json");
        let argv = vec![
            "kernel-bench".to_string(),
            "--smoke".to_string(),
            "--json".to_string(),
            path.to_string_lossy().into_owned(),
        ];
        let args = Args::parse(argv).unwrap();
        cmd_kernel_bench(&args).unwrap();
        let payload = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let kernels = payload.at(&["kernels"]).as_arr().unwrap();
        for fam in FAMILIES {
            assert!(
                kernels.iter().any(|r| r.at(&["family"]).as_str() == Some(fam)),
                "family {fam} missing from the sweep"
            );
        }
        for r in kernels {
            assert!(r.at(&["scalar", "gflops"]).as_f64().unwrap() > 0.0);
            assert!(r.at(&["micro", "gflops"]).as_f64().unwrap() > 0.0);
            assert_eq!(r.at(&["parity"]).as_str(), Some("bitwise"));
        }
        let _ = std::fs::remove_file(&path);
    }
}
