//! Plumbing shared by CLI commands and experiment drivers: engine
//! construction, checkpoint paths, pruner construction, and the
//! pretrain/prune/eval/probe/simulate commands.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::coordinator::{trainer, BlockPruner, Pipeline, PruneRun};
use crate::data::batcher::CalibrationSet;
use crate::model::ParamStore;
use crate::prune::besa::{BesaConfig, BesaPruner, Granularity};
use crate::prune::importance::Metric;
use crate::prune::magnitude::MagnitudePruner;
use crate::prune::sparsegpt::SparseGptPruner;
use crate::prune::wanda::WandaPruner;
use crate::prune::Method;
use crate::runtime::{BackendKind, Engine};
use crate::util::args::Args;

pub fn artifacts_root(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

/// Backend selection: `--backend native|pjrt` wins, then the
/// `BESA_BACKEND` env var, defaulting to the hermetic native interpreter.
pub fn backend_kind(args: &Args) -> Result<BackendKind> {
    match args.get("backend") {
        Some(b) => BackendKind::from_name(b)
            .with_context(|| format!("--backend must be native|pjrt, got '{b}'")),
        None => Ok(BackendKind::from_env()),
    }
}

/// Layered configuration: built-in defaults < TOML file < CLI flags.
/// The file is `--config-file <path>` or `configs/besa.toml` when present.
pub fn file_config(args: &Args) -> crate::util::toml::Toml {
    let path = match args.get("config-file") {
        Some(p) => PathBuf::from(p),
        None => PathBuf::from("configs/besa.toml"),
    };
    if path.exists() {
        match crate::util::toml::Toml::load(&path) {
            Ok(t) => {
                crate::debuglog!("loaded config file {}", path.display());
                t
            }
            Err(e) => {
                crate::warnlog!("ignoring bad config file {}: {e:#}", path.display());
                crate::util::toml::Toml::default()
            }
        }
    } else {
        crate::util::toml::Toml::default()
    }
}

pub fn runs_dir(args: &Args) -> PathBuf {
    let d = PathBuf::from(args.str_or("runs", "runs"));
    std::fs::create_dir_all(&d).ok();
    d
}

pub fn engine_for(args: &Args, config: &str) -> Result<Engine> {
    let kind = backend_kind(args)?;
    let engine = Engine::with_backend(kind, &artifacts_root(args), config)?;
    crate::debuglog!("engine: backend={} config={config}", engine.backend_name());
    Ok(engine)
}

pub fn dense_ckpt_path(args: &Args, config: &str) -> PathBuf {
    runs_dir(args).join(format!("{config}-dense.bst"))
}

/// Load a checkpoint; `--ckpt` wins, else the default dense path.
pub fn load_params(args: &Args, engine: &Engine) -> Result<ParamStore> {
    let cfg = engine.config();
    let path = match args.get("ckpt") {
        Some(p) => PathBuf::from(p),
        None => dense_ckpt_path(args, &cfg.name),
    };
    if !path.exists() {
        bail!(
            "checkpoint {} not found — run `besa pretrain --config {}` first",
            path.display(),
            cfg.name
        );
    }
    ParamStore::load(cfg, &path)
}

/// Build the pruner named by `--method` at `--sparsity`.
pub fn make_pruner(method: Method, sparsity: f64, args: &Args) -> Result<Box<dyn BlockPruner>> {
    Ok(match method {
        Method::Magnitude => Box::new(MagnitudePruner { sparsity }),
        Method::Wanda => Box::new(WandaPruner { sparsity }),
        Method::SparseGpt => Box::new(SparseGptPruner {
            sparsity,
            blocksize: args.usize_or("obs-blocksize", 32)?,
            percdamp: args.f64_or("percdamp", 0.01)?,
        }),
        Method::Besa => Box::new(BesaPruner::new(besa_config(sparsity, args)?)),
        Method::Dense => bail!("method 'dense' is not a pruner"),
    })
}

pub fn besa_config(sparsity: f64, args: &Args) -> Result<BesaConfig> {
    let file = file_config(args);
    Ok(BesaConfig {
        sparsity,
        epochs: args.usize_or("epochs", file.usize_or("prune.epochs", 24))?,
        lr: args.f32_or("lr", file.f64_or("prune.lr", 5e-2) as f32)?,
        lambda: args.f32_or("lambda", file.f64_or("prune.lambda", 8.0) as f32)?,
        row_wise: if args.has("layerwise") { false } else { file.bool_or("prune.rowwise", true) },
        granularity: match args
            .str_or("granularity", &file.str_or("prune.granularity", "block"))
            .as_str()
        {
            "attn-mlp" | "attn_mlp" => Granularity::AttnMlp,
            _ => Granularity::Block,
        },
        metric: Metric::from_name(&args.str_or("metric", &file.str_or("prune.metric", "wanda")))
            .context("--metric must be weight|wanda|sparsegpt")?,
        quant: args.has("quant"),
        grad_accum: args.usize_or("grad-accum", file.usize_or("prune.grad_accum", 1))?,
    })
}

pub fn calibration(args: &Args, engine: &Engine) -> Result<CalibrationSet> {
    let cfg = engine.config();
    let file = file_config(args);
    let n = args.usize_or("calib-seqs", file.usize_or("calib.seqs", 4 * cfg.batch))?;
    Ok(CalibrationSet::sample(
        cfg,
        n,
        args.u64_or("calib-seed", file.usize_or("calib.seed", 0xCA11B) as u64)?,
    ))
}

/// Prune `params` in place; returns the run telemetry.
pub fn prune_with(
    engine: &Engine,
    params: &mut ParamStore,
    method: Method,
    sparsity: f64,
    args: &Args,
) -> Result<PruneRun> {
    let calib = calibration(args, engine)?;
    let pipeline = Pipeline::new(engine, calib.batches);
    let mut pruner = make_pruner(method, sparsity, args)?;
    pipeline.run(params, pruner.as_mut())
}

// ---------------------------------------------------------------------------
// commands
// ---------------------------------------------------------------------------

pub fn cmd_pretrain(args: &Args) -> Result<()> {
    let config = args.str_or("config", "sm");
    let engine = engine_for(args, &config)?;
    let cfg = engine.config().clone();
    let mut params = ParamStore::init(&cfg, args.u64_or("seed", 1234)?);
    let tc = trainer::TrainConfig {
        steps: args.usize_or("steps", 300)?,
        lr: args.f32_or("lr", 3e-3)?,
        seed: args.u64_or("seed", 1234)?,
        log_every: args.usize_or("log-every", 20)?,
    };
    let stats = trainer::pretrain(&engine, &mut params, &tc)?;
    let out = match args.get("out") {
        Some(p) => PathBuf::from(p),
        None => dense_ckpt_path(args, &config),
    };
    params.save(&out)?;
    let first = stats.losses.first().copied().unwrap_or(0.0);
    let last = stats.losses.last().copied().unwrap_or(0.0);
    println!(
        "pretrained {config}: {} params, {} steps, loss {first:.3} -> {last:.3}, {:.1}s, saved {}",
        cfg.total_param_count(),
        stats.losses.len(),
        stats.secs,
        out.display()
    );
    Ok(())
}

pub fn cmd_prune(args: &Args) -> Result<()> {
    let config = args.str_or("config", "sm");
    let engine = engine_for(args, &config)?;
    let method = Method::from_name(&args.str_or("method", "besa"))
        .context("--method must be besa|wanda|sparsegpt|magnitude")?;
    let sparsity = args.f64_or("sparsity", 0.5)?;
    let mut params = load_params(args, &engine)?;
    let run = prune_with(&engine, &mut params, method, sparsity, args)?;
    let out = match args.get("out") {
        Some(p) => PathBuf::from(p),
        None => runs_dir(args).join(format!("{config}-{}.bst", method.name())),
    };
    params.save(&out)?;
    let cfg = engine.config();
    println!(
        "pruned {config} with {}: global sparsity {:.4}, {:.1}s, saved {}",
        method.name(),
        params.prunable_sparsity(cfg.n_blocks),
        run.secs,
        out.display()
    );
    for r in &run.reports {
        println!(
            "  block {}: sparsity {:.4} recon {:.3e}",
            r.block,
            r.mean_sparsity(cfg),
            r.recon_error
        );
    }
    Ok(())
}

pub fn cmd_eval(args: &Args) -> Result<()> {
    let config = args.str_or("config", "sm");
    let engine = engine_for(args, &config)?;
    let params = load_params(args, &engine)?;
    let n = args.usize_or("eval-batches", 16)?;
    for (domain, ppl) in crate::eval::perplexity_all(&engine, &params, n, 77)? {
        println!("{domain:>10}: ppl {ppl:.4}");
    }
    Ok(())
}

pub fn cmd_probe(args: &Args) -> Result<()> {
    let config = args.str_or("config", "sm");
    let engine = engine_for(args, &config)?;
    let params = load_params(args, &engine)?;
    let n = args.usize_or("items", 50)?;
    for r in crate::eval::probes::run_all(&engine, &params, n, 99)? {
        println!("{:>12}: {:.2}% ({} items)", r.task, r.accuracy * 100.0, r.items);
    }
    Ok(())
}

/// Parse the KV-backing flags shared by `serve-bench` and `serve-net`:
/// `--kv contig|paged`, `--kv-page <tokens/page>` and `--kv-pages <cap>`
/// (0 = unbounded pool).
pub(crate) fn parse_kv_mode(args: &Args) -> Result<crate::serve::KvMode> {
    use crate::serve::KvMode;
    match args.str_or("kv", "contig").as_str() {
        "contig" => Ok(KvMode::Contig),
        "paged" => {
            let page_tokens = args.usize_or("kv-page", 16)?;
            if page_tokens == 0 {
                bail!("--kv-page must be >= 1");
            }
            Ok(KvMode::Paged { page_tokens, max_pages: args.usize_or("kv-pages", 0)? })
        }
        other => bail!("--kv must be contig|paged, got '{other}'"),
    }
}

/// `besa serve-bench`: replay a Poisson/bursty request trace through the
/// sparse serving engine in each weight format and report throughput /
/// latency / speedup (+ `BENCH_serve.json`). `--async` adds the online
/// multi-worker mode: wall-clock ingestion (`--time-scale`, or
/// `--closed-loop N` clients) into `--workers` sharded workers, reported
/// at one worker and at N for the scaling. `--overload-sweep` adds
/// goodput-vs-offered-load curves per queue policy (`--deadline-ms`,
/// `--overload-multipliers`, `--policies`). `--kv paged` serves through
/// the paged allocator (`--kv-page` tokens per page, `--share-prefix`
/// for COW prompt-prefix sharing) and adds the paged-vs-contiguous
/// section to the record. `--trace-out <path>` dumps per-request
/// telemetry spans as JSONL. `--faults <spec> [--fault-seed N]` injects
/// deterministic worker panics / stalls / admission denials into the
/// async sections (grammar in `docs/robustness.md`); `--degrade <s>`
/// adds the shed-only vs sparsity-tiered-degradation goodput comparison
/// (a second replica set pruned to sparsity `s`) to the overload sweep.
/// `--smoke`/`--synthetic` build a magnitude-pruned checkpoint in
/// process so the run is hermetic.
pub fn cmd_serve_bench(args: &Args) -> Result<()> {
    use crate::serve::bench::{
        magnitude_prune_in_place, OnlineBenchConfig, OverloadSweepConfig, ServeMode,
    };
    use crate::serve::model::WeightFormat;
    use crate::serve::{FaultPlan, Pacing, Policy, SchedulerConfig, ServeBenchConfig, TraceConfig};

    let smoke = args.has("smoke");
    let config = args.str_or("config", if smoke { "test" } else { "sm" });
    let engine = engine_for(args, &config)?;
    let cfg = engine.config().clone();

    let params = if smoke || args.has("synthetic") {
        let mut p = ParamStore::init(&cfg, args.u64_or("seed", 1234)?);
        magnitude_prune_in_place(&mut p, &cfg, args.f64_or("sparsity", 0.5)?)?;
        p
    } else {
        load_params(args, &engine)?
    };

    let modes: Vec<ServeMode> = args
        .list_or("modes", &["dense", "sparse", "quant", "dense-backend"])
        .iter()
        .map(|m| {
            ServeMode::from_name(m)
                .with_context(|| format!("--modes: unknown mode '{m}' (dense|sparse|quant|dense-backend)"))
        })
        .collect::<Result<_>>()?;

    // trace scale: full defaults sized for the sm config; --smoke only
    // shrinks the *defaults* to a few seconds of CI work — explicit
    // flags always win in both branches
    let (d_req, d_rate, d_pmin, d_pmax, d_gmin, d_gmax) = if smoke {
        (8, 64.0, 8, 16, 4, 8)
    } else {
        (48, 24.0, 16, cfg.seq_len.max(17) - 1, 8, 24)
    };
    // base-trace QoS: `--deadline-ms 0` (the default) disables deadlines;
    // the overload sweep carries its own deadline default below
    let deadline_ms = args.f64_or("deadline-ms", 0.0)?;
    let trace = TraceConfig {
        n_requests: args.usize_or("requests", d_req)?,
        rate: args.f64_or("rate", d_rate)?,
        prompt_min: args.usize_or("prompt-min", d_pmin)?,
        prompt_max: args.usize_or("prompt-max", d_pmax)?,
        gen_min: args.usize_or("gen-min", d_gmin)?,
        gen_max: args.usize_or("gen-max", d_gmax)?,
        score_fraction: args.f64_or("score-fraction", 0.25)?,
        burst: args.usize_or("burst", 1)?,
        seed: args.u64_or("trace-seed", 0x7ACE)?,
        deadline_min_s: deadline_ms.max(0.0) / 1e3,
        deadline_max_s: deadline_ms.max(0.0) / 1e3,
        priority_tiers: args.usize_or("priority-tiers", 1)?.clamp(1, 255) as u8,
        clients: args.usize_or("trace-clients", 1)?.max(1) as u32,
        shared_prefix_len: args.usize_or("shared-prefix-tokens", 0)?,
    };
    let sched = SchedulerConfig {
        token_budget: args.usize_or("token-budget", if smoke { 256 } else { 1024 })?,
        max_batch: args.usize_or("max-batch", 8)?,
    };
    let policy = {
        let name = args.str_or("policy", "fifo");
        Policy::from_name(&name)
            .with_context(|| format!("--policy must be fifo|priority|edf, got '{name}'"))?
    };
    let queue_cap = args.usize_or("queue-cap", 0)?;
    // `--faults panic@decode:3,stall@prefill%7 --fault-seed 1`: the
    // deterministic fault schedule threaded into every async section
    let faults = match args.get("faults") {
        Some(spec) => Some(std::sync::Arc::new(
            FaultPlan::parse(spec, args.u64_or("fault-seed", 0xFA17)?)
                .context("--faults: bad fault spec")?,
        )),
        None => None,
    };
    // `--async`: the online multi-worker section. Pacing is closed-loop
    // when `--closed-loop N` is given, else wall-clock trace replay at
    // `--time-scale` (smoke defaults to 0 — flood the queue and measure
    // pure drain throughput, the deterministic-duration CI mode).
    let parse_format = |flag: &str, name: String| -> Result<WeightFormat> {
        Ok(match name.as_str() {
            "dense" => WeightFormat::Dense,
            "sparse" | "csr" => WeightFormat::Csr,
            "quant" => WeightFormat::Quant(crate::quant::QuantSpec::default()),
            other => bail!("--{flag} must be dense|sparse|quant, got '{other}'"),
        })
    };
    let online = if args.has("async") {
        let format = parse_format("async-format", args.str_or("async-format", "sparse"))?;
        let clients = args.usize_or("closed-loop", 0)?;
        let pacing = if clients > 0 {
            Pacing::ClosedLoop { clients }
        } else {
            Pacing::Replay {
                time_scale: args.f64_or("time-scale", if smoke { 0.0 } else { 1.0 })?,
            }
        };
        Some(OnlineBenchConfig {
            workers: args.usize_or("workers", 4)?,
            format,
            pacing,
            policy,
            queue_cap,
        })
    } else {
        None
    };
    // `--overload-sweep`: goodput-vs-offered-load curves for every queue
    // policy (or `--policies fifo,edf`), replaying one seeded trace at
    // each `--overload-multipliers` point under a uniform `--deadline-ms`
    let overload = if args.has("overload-sweep") {
        let defaults = OverloadSweepConfig::default();
        let multipliers = match args.get("overload-multipliers") {
            None => defaults.multipliers,
            Some(_) => args
                .list_or("overload-multipliers", &[])
                .iter()
                .map(|m| {
                    m.parse::<f64>()
                        .with_context(|| format!("--overload-multipliers: bad float '{m}'"))
                })
                .collect::<Result<_>>()?,
        };
        let policies = match args.get("policies") {
            None => defaults.policies,
            Some(_) => args
                .list_or("policies", &[])
                .iter()
                .map(|p| {
                    Policy::from_name(p)
                        .with_context(|| format!("--policies: unknown policy '{p}'"))
                })
                .collect::<Result<_>>()?,
        };
        Some(OverloadSweepConfig {
            multipliers,
            policies,
            workers: args.usize_or("workers", defaults.workers)?,
            format: parse_format("sweep-format", args.str_or("sweep-format", "sparse"))?,
            deadline_s: if deadline_ms > 0.0 { deadline_ms / 1e3 } else { defaults.deadline_s },
            queue_cap: args.usize_or("queue-cap", defaults.queue_cap)?,
            admit_reject: !args.has("no-admit-reject"),
            degrade_sparsity: match args.get("degrade") {
                Some(s) => {
                    let ds = s
                        .parse::<f64>()
                        .with_context(|| format!("--degrade: bad sparsity '{s}'"))?;
                    Some(ds)
                }
                None => None,
            },
        })
    } else {
        None
    };
    let bcfg = ServeBenchConfig {
        modes,
        trace,
        sched,
        quant: crate::quant::QuantSpec::default(),
        kv: parse_kv_mode(args)?,
        share_prefix: args.has("share-prefix"),
        parity_decode_tokens: args.usize_or("parity-tokens", if smoke { 4 } else { 8 })?,
        online,
        overload,
        json_path: match args.get("json") {
            Some("none") => None,
            Some(p) => Some(PathBuf::from(p)),
            None => Some(PathBuf::from("BENCH_serve.json")),
        },
        trace_out: args.get("trace-out").map(PathBuf::from),
        faults,
    };
    crate::serve::bench::run_serve_bench(&engine, &params, &bcfg)?;
    Ok(())
}

pub fn cmd_simulate(args: &Args) -> Result<()> {
    let config = args.str_or("config", "sm");
    let engine = engine_for(args, &config)?;
    let params = load_params(args, &engine)?;
    let cfg = engine.config();
    let sim = crate::sim::SimConfig {
        tokens: args.usize_or("tokens", cfg.seq_len)?,
        ..Default::default()
    };
    println!(
        "{:<10} {:>6}x{:<6} {:>9} {:>12} {:>12} {:>8} {:>6}",
        "layer", "out", "in", "sparsity", "dense cyc", "sparse cyc", "speedup", "util"
    );
    for s in crate::sim::simulate_block(&params, cfg, &sim)? {
        println!(
            "{:<10} {:>6}x{:<6} {:>8.2}% {:>12} {:>12} {:>7.2}x {:>6.2}",
            s.layer,
            s.rows,
            s.cols,
            s.sparsity * 100.0,
            s.dense_cycles,
            s.sparse_cycles,
            s.speedup,
            s.utilization
        );
    }
    Ok(())
}
