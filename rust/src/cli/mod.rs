//! Command-line interface: subcommand dispatch for the `besa` binary.
//!
//! ```text
//! besa pretrain  --config md --steps 300 --out runs/md.bst
//! besa prune     --config md --ckpt runs/md.bst --method besa --sparsity 0.5
//! besa eval      --config md --ckpt runs/md-besa.bst
//! besa probe     --config md --ckpt runs/md-besa.bst
//! besa simulate  --config md --ckpt runs/md-besa.bst
//! besa serve-bench --config sm --ckpt runs/sm-besa.bst --modes dense,sparse,quant
//! besa serve-bench --config sm --ckpt runs/sm-besa.bst --async --workers 4
//! besa serve-bench --config sm --ckpt runs/sm-besa.bst --overload-sweep --deadline-ms 250
//! besa serve-bench --smoke --overload-sweep --degrade 0.9 --faults stall@decode%40
//! besa serve-net  --smoke --drive --policy edf --deadline-ms 40 --trace-out spans.jsonl
//! besa serve-net  --smoke --drive --faults panic@decode:3,disconnect@stream%5 --degrade 0.9
//! besa kernel-bench --json BENCH_kernels.json
//! besa exp       table1|table2|table3|table4|table5|table6|fig1a|fig1b|fig3|fig4  [--configs sm,md]
//! ```

pub mod analyze;
pub mod exp;
pub mod kernels;
pub mod net;
pub mod runs;

use anyhow::{bail, Result};

use crate::util::args::Args;

pub fn main(argv: Vec<String>) -> Result<()> {
    crate::util::logging::init_from_env();
    let args = Args::parse(argv)?;
    if let Some(lvl) = args.get("log") {
        crate::util::logging::set_level_str(lvl);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "pretrain" => runs::cmd_pretrain(&args),
        "prune" => runs::cmd_prune(&args),
        "eval" => runs::cmd_eval(&args),
        "probe" => runs::cmd_probe(&args),
        "simulate" => runs::cmd_simulate(&args),
        "serve-bench" => runs::cmd_serve_bench(&args),
        "serve-net" => net::cmd_serve_net(&args),
        "kernel-bench" => kernels::cmd_kernel_bench(&args),
        "analyze" => analyze::cmd_analyze(&args),
        "exp" => exp::dispatch(&args),
        "help" | _ => {
            print_help();
            if cmd != "help" {
                bail!("unknown command '{cmd}'");
            }
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "besa — BESA pruning reproduction (ICLR 2024)\n\
         \n\
         USAGE: besa <command> [options]\n\
         \n\
         COMMANDS\n\
         \x20 pretrain   train a dense LM checkpoint on the synthetic corpus\n\
         \x20 prune      prune a checkpoint (besa|wanda|sparsegpt|magnitude)\n\
         \x20 eval       perplexity on wiki-syn / c4-syn / ptb-syn\n\
         \x20 probe      zero-shot probe accuracy (6 tasks)\n\
         \x20 simulate   ViTCoD accelerator cycles for a pruned checkpoint\n\
         \x20 serve-bench  batch-serve a pruned checkpoint: Poisson/bursty trace,\n\
         \x20            continuous batching, dense/sparse/quant kernels, throughput\n\
         \x20            + latency (--smoke: tiny hermetic run on a synthetic pruned\n\
         \x20            model; --modes dense,sparse,quant,dense-backend;\n\
         \x20            --burst <k>; --json <path>). --async adds the online\n\
         \x20            multi-worker mode: wall-clock ingestion into --workers <n>\n\
         \x20            sharded workers (--time-scale <x>: 0 floods the queue;\n\
         \x20            --closed-loop <clients>; --async-format dense|sparse|quant),\n\
         \x20            reported at 1 and n workers with the scaling + queue-wait\n\
         \x20            breakdown. --overload-sweep adds goodput-vs-offered-load\n\
         \x20            curves per queue policy (--deadline-ms <ms>;\n\
         \x20            --overload-multipliers 0.5,1,2,4,8; --policies fifo,edf;\n\
         \x20            --queue-cap <n>). --kv contig|paged picks the KV backing\n\
         \x20            (--kv-page <tokens/page>, --kv-pages <pool cap, 0=unbounded>,\n\
         \x20            --share-prefix for COW prompt-prefix sharing,\n\
         \x20            --shared-prefix-tokens <n> prepends a common prompt prefix\n\
         \x20            to the trace); paged adds the paged-vs-contig section to\n\
         \x20            the record. --trace-out <path> dumps per-request\n\
         \x20            telemetry spans as JSONL (docs/telemetry.md).\n\
         \x20            --faults <spec> [--fault-seed <n>] injects deterministic\n\
         \x20            worker panics/stalls/denials into the async sections;\n\
         \x20            --degrade <sparsity> adds the shed-only vs sparsity-tiered\n\
         \x20            degradation goodput comparison to the overload sweep\n\
         \x20            (docs/robustness.md)\n\
         \x20 serve-net  TCP front end over the same workers: line-delimited JSON\n\
         \x20            + an HTTP/1.1 subset (GET /healthz, POST /v1/generate),\n\
         \x20            per-client token buckets (--bucket-rate, --bucket-burst),\n\
         \x20            deadline shedding (--deadline-reject), bounded queue\n\
         \x20            (--queue-cap), --policy fifo|priority|edf, graceful drain\n\
         \x20            (--drain-deadline-s), --kv contig|paged (+ --kv-page,\n\
         \x20            --kv-pages, --steal, --share-prefix: paged allocator, decode\n\
         \x20            work stealing, prefix sharing). --drive runs the hermetic\n\
         \x20            loopback self-test (--clients, --requests, --deadline-ms).\n\
         \x20            --faults <spec> [--fault-seed <n>]: worker panics/stalls/\n\
         \x20            denials fire server-side, disconnect@stream hangs up the\n\
         \x20            drive clients mid-stream; --retry-budget <n> caps replays;\n\
         \x20            --degrade <sparsity> serves pressured admissions from a\n\
         \x20            sparser replica tier instead of shedding (docs/robustness.md).\n\
         \x20            --addr <ip:port> binds (port 0 = ephemeral); docs/serving.md\n\
         \x20 kernel-bench  roofline sweep of the shared microkernel layer:\n\
         \x20            scalar reference vs micro kernel per family (matvec,\n\
         \x20            GEMMs, CSR/quant SpMM, attention rows), bitwise parity\n\
         \x20            checked per shape, GFLOP/s into BENCH_kernels.json\n\
         \x20            (--smoke: tiny CI shapes; --json <path>)\n\
         \x20 analyze    static analysis: artifact-graph shape checker over the\n\
         \x20            synthesized manifests + repo-specific source lints\n\
         \x20            (hot-path panics, lock-order cycles, determinism).\n\
         \x20            Nonzero exit on any unsuppressed finding.\n\
         \x20            (--src <dir>; --configs test,sm,md,lg; --json <path>)\n\
         \x20 exp        regenerate a paper table/figure (table1..table6, fig1a, fig1b, fig3, fig4)\n\
         \n\
         COMMON OPTIONS\n\
         \x20 --config <test|sm|md|lg>     model config (default sm)\n\
         \x20 --backend <native|pjrt>      execution backend (default native;\n\
         \x20                              env: BESA_BACKEND). native needs no\n\
         \x20                              artifacts; pjrt needs `make artifacts`\n\
         \x20                              and a build with --features pjrt\n\
         \x20 --artifacts <dir>            artifact root (default ./artifacts)\n\
         \x20 --runs <dir>                 checkpoint/run dir (default ./runs)\n\
         \x20 --log <level>                error|warn|info|debug|trace\n"
    );
}
