//! `besa analyze` — run the static-analysis subsystem from the CLI.
//!
//! Scans a Rust source tree with the repo-specific lints, graph-checks
//! the synthesized manifests of the requested built-in configs, prints
//! every finding, and exits nonzero if any finding is unsuppressed —
//! which is exactly what the CI gate keys on. `--json <path>` writes the
//! machine-readable report for tooling.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::util::args::Args;

pub fn cmd_analyze(args: &Args) -> Result<()> {
    let src = match args.get("src") {
        Some(p) => PathBuf::from(p),
        None => {
            // default: run from the repo root or from rust/
            let nested = PathBuf::from("rust/src");
            let local = PathBuf::from("src");
            if nested.is_dir() {
                nested
            } else if local.is_dir() {
                local
            } else {
                bail!("no source tree found — pass --src <dir> (tried rust/src and src)")
            }
        }
    };
    let configs = args.list_or("configs", &["test", "sm", "md", "lg"]);
    let report = crate::analyze::analyze_repo(&src, &configs)?;
    if let Some(path) = args.get("json") {
        std::fs::write(path, report.to_json().to_string_pretty())
            .with_context(|| format!("writing {path}"))?;
        println!("analyze: wrote {path}");
    }
    println!(
        "analyze: {} files scanned, {} config(s) graph-checked, {} finding(s) suppressed by \
         inline allows",
        report.files_scanned,
        report.configs_checked.len(),
        report.suppressed
    );
    if report.clean() {
        println!("analyze: clean");
        Ok(())
    } else {
        for d in &report.findings {
            println!("{}", d.render());
        }
        bail!("analyze: {} unsuppressed finding(s)", report.findings.len())
    }
}
