//! `besa serve-net`: stand up the TCP front end ([`crate::serve::net`])
//! over a pruned checkpoint — or, with `--drive`, run a hermetic
//! loopback self-test: spawn the server on an ephemeral port, drive a
//! seeded trace through concurrent line-protocol clients, then shut
//! down gracefully and verify the overload-control accounting
//! (`queued == finished + shed + failed`, clean drain, telemetry
//! non-empty). This is what the CI serve-net smoke and chaos jobs run.
//!
//! `--faults <spec>` threads a deterministic fault schedule through the
//! run: `panic`/`stall`/`deny` clauses fire inside the server workers,
//! while `disconnect@stream` clauses fire *client-side* in the drive
//! loop — the driver hangs up mid-stream and reconnects, and the
//! accounting cross-check then tolerates exactly that: every surplus
//! server-side finish or failure must map to one client hang-up.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::model::ParamStore;
use crate::serve::bench::magnitude_prune_in_place;
use crate::serve::net::{request_line, WireEvent};
use crate::serve::{
    poisson_trace, FaultAction, FaultPlan, FaultSite, LineClient, NetConfig, NetServer, NetStats,
    PackedModel, Policy, Request, SchedulerConfig, ServeContext, TraceConfig, WeightFormat,
};
use crate::telemetry::Tracer;
use crate::util::args::Args;
use crate::util::par::scoped_workers;

use super::runs::{engine_for, load_params, parse_kv_mode};

/// What one drive client observed, summed over its share of the trace.
#[derive(Debug, Default, Clone, Copy)]
struct DriveCounts {
    done: usize,
    within_deadline: usize,
    degraded: usize,
    shed: usize,
    rejected: usize,
    failed: usize,
    disconnected: usize,
    errors: usize,
}

pub fn cmd_serve_net(args: &Args) -> Result<()> {
    let smoke = args.has("smoke");
    let config = args.str_or("config", if smoke { "test" } else { "sm" });
    let engine = engine_for(args, &config)?;
    let cfg = engine.config().clone();

    let params = if smoke || args.has("synthetic") {
        let mut p = ParamStore::init(&cfg, args.u64_or("seed", 1234)?);
        magnitude_prune_in_place(&mut p, &cfg, args.f64_or("sparsity", 0.5)?)?;
        p
    } else {
        load_params(args, &engine)?
    };

    let format = match args.str_or("format", "sparse").as_str() {
        "dense" => WeightFormat::Dense,
        "sparse" | "csr" => WeightFormat::Csr,
        "quant" => WeightFormat::Quant(crate::quant::QuantSpec::default()),
        other => bail!("--format must be dense|sparse|quant, got '{other}'"),
    };
    let policy = {
        let name = args.str_or("policy", "fifo");
        Policy::from_name(&name)
            .with_context(|| format!("--policy must be fifo|priority|edf, got '{name}'"))?
    };
    let sched = SchedulerConfig {
        token_budget: args.usize_or("token-budget", if smoke { 256 } else { 1024 })?,
        max_batch: args.usize_or("max-batch", 8)?,
    };
    // `--faults panic@decode:3,disconnect@stream%5 --fault-seed 1`:
    // worker-side clauses ride in NetConfig; stream clauses fire in the
    // drive loop below (same plan, so hit counters are shared)
    let faults = match args.get("faults") {
        Some(spec) => Some(Arc::new(
            FaultPlan::parse(spec, args.u64_or("fault-seed", 0xFA17)?)
                .context("--faults: bad fault spec")?,
        )),
        None => None,
    };
    let ncfg = NetConfig {
        addr: args.str_or("addr", "127.0.0.1:0"),
        workers: args.usize_or("workers", 2)?,
        sched,
        policy,
        queue_cap: args.usize_or("queue-cap", 256)?,
        bucket_rate: args.f64_or("bucket-rate", 0.0)?,
        bucket_burst: args.f64_or("bucket-burst", 0.0)?,
        admit_reject: args.has("deadline-reject"),
        kv: parse_kv_mode(args)?,
        steal: args.has("steal"),
        share_prefix: args.has("share-prefix"),
        drain_deadline: Duration::from_secs_f64(args.f64_or("drain-deadline-s", 10.0)?),
        faults: faults.clone(),
        retry_budget: args.usize_or("retry-budget", 2)? as u32,
        ..NetConfig::default()
    };

    // every admissible request must fit the smallest replica
    let max_pos = args.usize_or("max-pos", ncfg.sched.token_budget)?;
    let ctxs = (0..ncfg.workers)
        .map(|_| Ok(ServeContext::new(PackedModel::materialize(&params, &cfg, format)?, max_pos)))
        .collect::<Result<Vec<_>>>()?;
    // `--degrade <sparsity>`: a second, sparser replica per worker;
    // pressured admissions are answered from it instead of shed
    let degrade_ctxs = match args.get("degrade") {
        Some(s) => {
            let ds = s
                .parse::<f64>()
                .with_context(|| format!("--degrade: bad sparsity '{s}'"))?;
            let mut dparams = params.clone();
            magnitude_prune_in_place(&mut dparams, &cfg, ds)?;
            Some(
                (0..ncfg.workers)
                    .map(|_| {
                        Ok(ServeContext::new(
                            PackedModel::materialize(&dparams, &cfg, format)?,
                            max_pos,
                        ))
                    })
                    .collect::<Result<Vec<_>>>()?,
            )
        }
        None => None,
    };

    let trace_out = args.get("trace-out").map(PathBuf::from);
    let tracer = trace_out.as_ref().map(|_| Arc::new(Tracer::new()));

    let server = NetServer::start_tiered(ctxs, degrade_ctxs, ncfg.clone(), tracer.clone())?;
    let addr = server.addr();
    println!(
        "serve-net: {} on {addr} ({} workers, policy {}, queue cap {}, kv {})",
        format.name(),
        ncfg.workers,
        ncfg.policy.name(),
        ncfg.queue_cap,
        ncfg.kv.name()
    );

    let outcome = if args.has("drive") {
        drive_loopback(args, smoke, server, &addr, faults.as_deref())
    } else {
        let secs = args.f64_or("duration-s", 5.0)?;
        println!("serving for {secs:.1}s (pass --drive for the loopback self-test)");
        std::thread::sleep(Duration::from_secs_f64(secs));
        server.shutdown()
    };

    // flush spans even on an abnormal end (a failed drive, a fault
    // schedule that broke the run): the spans collected up to the
    // failure are exactly the ones worth reading
    let flush = || -> Result<()> {
        if let (Some(path), Some(t)) = (&trace_out, &tracer) {
            let n = t.write_jsonl(path)?;
            println!("[telemetry: {n} spans -> {}]", path.display());
            if n == 0 {
                bail!("telemetry dump is empty — spans were never recorded");
            }
        }
        Ok(())
    };
    let stats = match outcome {
        Ok(s) => {
            flush()?;
            s
        }
        Err(e) => {
            let _ = flush();
            return Err(e);
        }
    };

    print_stats(&stats);
    if !stats.drained_clean {
        bail!("graceful drain missed the deadline: connections still open at shutdown");
    }
    if !stats.accounted() {
        bail!(
            "accounting violated: {} queued but {} finished + {} shed + {} failed",
            stats.requests,
            stats.finished.len(),
            stats.shed.len(),
            stats.failed.len()
        );
    }
    Ok(())
}

/// The loopback self-test: drive a seeded trace through `--clients`
/// concurrent line-protocol connections as fast as they will go, then
/// drain and cross-check the client-side event counts against the
/// server-side accounting. `disconnect@stream` clauses of `faults` fire
/// here — the client hangs up mid-stream and reconnects.
fn drive_loopback(
    args: &Args,
    smoke: bool,
    server: NetServer,
    addr: &std::net::SocketAddr,
    faults: Option<&FaultPlan>,
) -> Result<NetStats> {
    let deadline_ms = args.f64_or("deadline-ms", if smoke { 250.0 } else { 0.0 })?;
    let (d_req, d_pmin, d_pmax, d_gmin, d_gmax) = if smoke {
        (32, 8, 16, 4, 8)
    } else {
        (128, 16, 48, 8, 24)
    };
    let nclients = args.usize_or("clients", 4)?.max(1);
    let tcfg = TraceConfig {
        n_requests: args.usize_or("requests", d_req)?,
        rate: args.f64_or("rate", 256.0)?,
        prompt_min: args.usize_or("prompt-min", d_pmin)?,
        prompt_max: args.usize_or("prompt-max", d_pmax)?,
        gen_min: args.usize_or("gen-min", d_gmin)?,
        gen_max: args.usize_or("gen-max", d_gmax)?,
        score_fraction: args.f64_or("score-fraction", 0.25)?,
        burst: args.usize_or("burst", 1)?,
        seed: args.u64_or("trace-seed", 0x7ACE)?,
        deadline_min_s: deadline_ms.max(0.0) / 1e3,
        deadline_max_s: deadline_ms.max(0.0) / 1e3,
        priority_tiers: args.usize_or("priority-tiers", 1)?.clamp(1, 255) as u8,
        clients: nclients as u32,
        shared_prefix_len: args.usize_or("shared-prefix-tokens", 0)?,
    };
    let requests = poisson_trace(&tcfg);
    let total = requests.len();
    println!("driving {total} requests over {nclients} clients (deadline {deadline_ms:.0} ms)");

    // shard round-robin by trace id; each client runs its share
    // sequentially, so concurrency (and queue pressure) == `nclients`
    let inject_disconnect = faults.map(|p| p.covers(FaultSite::Stream)).unwrap_or(false);
    let results = scoped_workers(nclients, |c| -> Result<DriveCounts> {
        let mut client = LineClient::connect(addr)?;
        let mut counts = DriveCounts::default();
        for req in requests.iter().filter(|r| r.id % nclients == c) {
            let hung_up = drive_one(&mut client, req, &mut counts, faults)?;
            if hung_up {
                // the socket is gone; the rest of this shard rides a
                // fresh connection
                client = LineClient::connect(addr)?;
            }
        }
        Ok(counts)
    });
    let mut agg = DriveCounts::default();
    for r in results {
        let c = r?;
        agg.done += c.done;
        agg.within_deadline += c.within_deadline;
        agg.degraded += c.degraded;
        agg.shed += c.shed;
        agg.rejected += c.rejected;
        agg.failed += c.failed;
        agg.disconnected += c.disconnected;
        agg.errors += c.errors;
    }
    println!(
        "clients saw: {} done ({} within deadline, {} degraded), {} shed, {} rejected, {} failed, {} disconnected, {} errors",
        agg.done, agg.within_deadline, agg.degraded, agg.shed, agg.rejected, agg.failed,
        agg.disconnected, agg.errors
    );
    let stats = server.shutdown()?;
    let client_total =
        agg.done + agg.shed + agg.rejected + agg.failed + agg.disconnected + agg.errors;
    if client_total != total {
        bail!("client accounting violated: {client_total} events for {total} requests");
    }
    if inject_disconnect {
        // a hung-up request lands server-side as either a finish (the
        // terminal was already in flight) or an abort-failure — every
        // surplus over what clients observed must map to one hang-up
        let surplus_done = stats.finished.len().checked_sub(agg.done);
        let surplus_failed = stats.failed.len().checked_sub(agg.failed);
        match (surplus_done, surplus_failed) {
            (Some(sd), Some(sf)) if sd + sf == agg.disconnected => {}
            _ => bail!(
                "client/server disagree under disconnects: clients saw {} done / {} failed / {} hang-ups, server {} / {}",
                agg.done,
                agg.failed,
                agg.disconnected,
                stats.finished.len(),
                stats.failed.len()
            ),
        }
    } else if agg.done != stats.finished.len()
        || agg.shed != stats.shed.len()
        || agg.failed != stats.failed.len()
    {
        bail!(
            "client/server disagree: clients saw {} done / {} shed / {} failed, server {} / {} / {}",
            agg.done,
            agg.shed,
            agg.failed,
            stats.finished.len(),
            stats.shed.len(),
            stats.failed.len()
        );
    }
    Ok(stats)
}

/// Send one trace request and fold its terminal event into `counts`.
/// Returns `true` when a `disconnect@stream` clause fired and the
/// connection was deliberately dropped mid-stream (the caller must
/// reconnect).
fn drive_one(
    client: &mut LineClient,
    req: &Request,
    counts: &mut DriveCounts,
    faults: Option<&FaultPlan>,
) -> Result<bool> {
    client.send_line(&request_line(req.id as u64, req))?;
    loop {
        let ev = client.read_event()?;
        match ev {
            WireEvent::Token { .. } => {
                if let Some(FaultAction::Disconnect) =
                    crate::serve::fault::fire(faults, FaultSite::Stream)
                {
                    counts.disconnected += 1;
                    return Ok(true); // caller replaces the client, closing this socket
                }
            }
            WireEvent::Done { deadline_met, degraded, .. } => {
                counts.done += 1;
                if deadline_met {
                    counts.within_deadline += 1;
                }
                if degraded {
                    counts.degraded += 1;
                }
                return Ok(false);
            }
            WireEvent::Failed { .. } => {
                counts.failed += 1;
                return Ok(false);
            }
            WireEvent::Shed { .. } => {
                counts.shed += 1;
                return Ok(false);
            }
            WireEvent::Rejected { .. } => {
                counts.rejected += 1;
                return Ok(false);
            }
            WireEvent::Error { .. } => {
                counts.errors += 1;
                return Ok(false);
            }
        }
    }
}

fn print_stats(stats: &NetStats) {
    let tokens: usize = stats.finished.iter().map(|f| f.tokens.len()).sum();
    println!(
        "server: {} conns, {} queued, {} finished ({} tokens, {} degraded), {} shed, {} queue-rejected",
        stats.accepted_conns,
        stats.requests,
        stats.finished.len(),
        tokens,
        stats.degraded(),
        stats.shed.len(),
        stats.rejected.len()
    );
    println!(
        "        {} rate-limited, {} parse errors, {} failed, {} restarts, {} requeues, drained clean: {}",
        stats.rejected_rate,
        stats.parse_errors,
        stats.failed.len(),
        stats.restarts,
        stats.requeues,
        stats.drained_clean
    );
    for w in &stats.workers {
        println!(
            "  worker {}: {} requests, {} prompt + {} gen tokens, busy {:.3}s",
            w.worker, w.requests, w.prompt_tokens, w.gen_tokens, w.busy_s
        );
    }
}
