//! `besa serve-net`: stand up the TCP front end ([`crate::serve::net`])
//! over a pruned checkpoint — or, with `--drive`, run a hermetic
//! loopback self-test: spawn the server on an ephemeral port, drive a
//! seeded trace through concurrent line-protocol clients, then shut
//! down gracefully and verify the overload-control accounting
//! (`queued == finished + shed`, clean drain, telemetry non-empty).
//! This is what the CI serve-net smoke job runs.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::model::ParamStore;
use crate::serve::bench::magnitude_prune_in_place;
use crate::serve::net::{request_line, WireEvent};
use crate::serve::{
    poisson_trace, LineClient, NetConfig, NetServer, NetStats, PackedModel, Policy, Request,
    SchedulerConfig, ServeContext, TraceConfig, WeightFormat,
};
use crate::telemetry::Tracer;
use crate::util::args::Args;
use crate::util::par::scoped_workers;

use super::runs::{engine_for, load_params, parse_kv_mode};

/// What one drive client observed, summed over its share of the trace.
#[derive(Debug, Default, Clone, Copy)]
struct DriveCounts {
    done: usize,
    within_deadline: usize,
    shed: usize,
    rejected: usize,
    errors: usize,
}

pub fn cmd_serve_net(args: &Args) -> Result<()> {
    let smoke = args.has("smoke");
    let config = args.str_or("config", if smoke { "test" } else { "sm" });
    let engine = engine_for(args, &config)?;
    let cfg = engine.config().clone();

    let params = if smoke || args.has("synthetic") {
        let mut p = ParamStore::init(&cfg, args.u64_or("seed", 1234)?);
        magnitude_prune_in_place(&mut p, &cfg, args.f64_or("sparsity", 0.5)?)?;
        p
    } else {
        load_params(args, &engine)?
    };

    let format = match args.str_or("format", "sparse").as_str() {
        "dense" => WeightFormat::Dense,
        "sparse" | "csr" => WeightFormat::Csr,
        "quant" => WeightFormat::Quant(crate::quant::QuantSpec::default()),
        other => bail!("--format must be dense|sparse|quant, got '{other}'"),
    };
    let policy = {
        let name = args.str_or("policy", "fifo");
        Policy::from_name(&name)
            .with_context(|| format!("--policy must be fifo|priority|edf, got '{name}'"))?
    };
    let sched = SchedulerConfig {
        token_budget: args.usize_or("token-budget", if smoke { 256 } else { 1024 })?,
        max_batch: args.usize_or("max-batch", 8)?,
    };
    let ncfg = NetConfig {
        addr: args.str_or("addr", "127.0.0.1:0"),
        workers: args.usize_or("workers", 2)?,
        sched,
        policy,
        queue_cap: args.usize_or("queue-cap", 256)?,
        bucket_rate: args.f64_or("bucket-rate", 0.0)?,
        bucket_burst: args.f64_or("bucket-burst", 0.0)?,
        admit_reject: args.has("deadline-reject"),
        kv: parse_kv_mode(args)?,
        steal: args.has("steal"),
        share_prefix: args.has("share-prefix"),
        drain_deadline: Duration::from_secs_f64(args.f64_or("drain-deadline-s", 10.0)?),
        ..NetConfig::default()
    };

    // every admissible request must fit the smallest replica
    let max_pos = args.usize_or("max-pos", ncfg.sched.token_budget)?;
    let ctxs = (0..ncfg.workers)
        .map(|_| Ok(ServeContext::new(PackedModel::materialize(&params, &cfg, format)?, max_pos)))
        .collect::<Result<Vec<_>>>()?;

    let trace_out = args.get("trace-out").map(PathBuf::from);
    let tracer = trace_out.as_ref().map(|_| Arc::new(Tracer::new()));

    let server = NetServer::start(ctxs, ncfg.clone(), tracer.clone())?;
    let addr = server.addr();
    println!(
        "serve-net: {} on {addr} ({} workers, policy {}, queue cap {}, kv {})",
        format.name(),
        ncfg.workers,
        ncfg.policy.name(),
        ncfg.queue_cap,
        ncfg.kv.name()
    );

    let stats = if args.has("drive") {
        drive_loopback(args, smoke, server, &addr)?
    } else {
        let secs = args.f64_or("duration-s", 5.0)?;
        println!("serving for {secs:.1}s (pass --drive for the loopback self-test)");
        std::thread::sleep(Duration::from_secs_f64(secs));
        server.shutdown()?
    };

    print_stats(&stats);
    if !stats.drained_clean {
        bail!("graceful drain missed the deadline: connections still open at shutdown");
    }
    if !stats.accounted() {
        bail!(
            "accounting violated: {} queued but {} finished + {} shed",
            stats.requests,
            stats.finished.len(),
            stats.shed.len()
        );
    }
    if let (Some(path), Some(t)) = (&trace_out, &tracer) {
        let n = t.write_jsonl(path)?;
        println!("[telemetry: {n} spans -> {}]", path.display());
        if n == 0 {
            bail!("telemetry dump is empty — spans were never recorded");
        }
    }
    Ok(())
}

/// The loopback self-test: drive a seeded trace through `--clients`
/// concurrent line-protocol connections as fast as they will go, then
/// drain and cross-check the client-side event counts against the
/// server-side accounting.
fn drive_loopback(
    args: &Args,
    smoke: bool,
    server: NetServer,
    addr: &std::net::SocketAddr,
) -> Result<NetStats> {
    let deadline_ms = args.f64_or("deadline-ms", if smoke { 250.0 } else { 0.0 })?;
    let (d_req, d_pmin, d_pmax, d_gmin, d_gmax) = if smoke {
        (32, 8, 16, 4, 8)
    } else {
        (128, 16, 48, 8, 24)
    };
    let nclients = args.usize_or("clients", 4)?.max(1);
    let tcfg = TraceConfig {
        n_requests: args.usize_or("requests", d_req)?,
        rate: args.f64_or("rate", 256.0)?,
        prompt_min: args.usize_or("prompt-min", d_pmin)?,
        prompt_max: args.usize_or("prompt-max", d_pmax)?,
        gen_min: args.usize_or("gen-min", d_gmin)?,
        gen_max: args.usize_or("gen-max", d_gmax)?,
        score_fraction: args.f64_or("score-fraction", 0.25)?,
        burst: args.usize_or("burst", 1)?,
        seed: args.u64_or("trace-seed", 0x7ACE)?,
        deadline_min_s: deadline_ms.max(0.0) / 1e3,
        deadline_max_s: deadline_ms.max(0.0) / 1e3,
        priority_tiers: args.usize_or("priority-tiers", 1)?.clamp(1, 255) as u8,
        clients: nclients as u32,
        shared_prefix_len: args.usize_or("shared-prefix-tokens", 0)?,
    };
    let requests = poisson_trace(&tcfg);
    let total = requests.len();
    println!("driving {total} requests over {nclients} clients (deadline {deadline_ms:.0} ms)");

    // shard round-robin by trace id; each client runs its share
    // sequentially, so concurrency (and queue pressure) == `nclients`
    let results = scoped_workers(nclients, |c| -> Result<DriveCounts> {
        let mut client = LineClient::connect(addr)?;
        let mut counts = DriveCounts::default();
        for req in requests.iter().filter(|r| r.id % nclients == c) {
            drive_one(&mut client, req, &mut counts)?;
        }
        Ok(counts)
    });
    let mut agg = DriveCounts::default();
    for r in results {
        let c = r?;
        agg.done += c.done;
        agg.within_deadline += c.within_deadline;
        agg.shed += c.shed;
        agg.rejected += c.rejected;
        agg.errors += c.errors;
    }
    println!(
        "clients saw: {} done ({} within deadline), {} shed, {} rejected, {} errors",
        agg.done, agg.within_deadline, agg.shed, agg.rejected, agg.errors
    );
    let stats = server.shutdown()?;
    if agg.done + agg.shed + agg.rejected + agg.errors != total {
        bail!(
            "client accounting violated: {} events for {} requests",
            agg.done + agg.shed + agg.rejected + agg.errors,
            total
        );
    }
    if agg.done != stats.finished.len() || agg.shed != stats.shed.len() {
        bail!(
            "client/server disagree: clients saw {} done / {} shed, server {} / {}",
            agg.done,
            agg.shed,
            stats.finished.len(),
            stats.shed.len()
        );
    }
    Ok(stats)
}

/// Send one trace request and fold its terminal event into `counts`.
fn drive_one(client: &mut LineClient, req: &Request, counts: &mut DriveCounts) -> Result<()> {
    let events = client.request(&request_line(req.id as u64, req))?;
    match events.last() {
        Some(WireEvent::Done { deadline_met, .. }) => {
            counts.done += 1;
            if *deadline_met {
                counts.within_deadline += 1;
            }
        }
        Some(WireEvent::Shed { .. }) => counts.shed += 1,
        Some(WireEvent::Rejected { .. }) => counts.rejected += 1,
        Some(WireEvent::Error { .. }) => counts.errors += 1,
        Some(WireEvent::Token { .. }) | None => {
            bail!("request {} ended without a terminal event", req.id)
        }
    }
    Ok(())
}

fn print_stats(stats: &NetStats) {
    let tokens: usize = stats.finished.iter().map(|f| f.tokens.len()).sum();
    println!(
        "server: {} conns, {} queued, {} finished ({} tokens), {} shed, {} queue-rejected",
        stats.accepted_conns,
        stats.requests,
        stats.finished.len(),
        tokens,
        stats.shed.len(),
        stats.rejected.len()
    );
    println!(
        "        {} rate-limited, {} parse errors, drained clean: {}",
        stats.rejected_rate, stats.parse_errors, stats.drained_clean
    );
    for w in &stats.workers {
        println!(
            "  worker {}: {} requests, {} prompt + {} gen tokens, busy {:.3}s",
            w.worker, w.requests, w.prompt_tokens, w.gen_tokens, w.busy_s
        );
    }
}
