//! Experiment drivers: one per paper table/figure (DESIGN.md experiment
//! index). Every driver prints the paper-style rows and appends a
//! machine-readable record to `results/<exp>.json`.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::eval::{perplexity, perplexity_all};
use crate::model::ParamStore;
use crate::prune::besa::{BesaConfig, BesaPruner};
use crate::prune::importance::Metric;
use crate::prune::Method;
use crate::util::args::Args;
use crate::util::json::{self, Json};

use super::runs;

pub fn dispatch(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .context("usage: besa exp <table1|table2|table3|table4|table5|table6|fig1a|fig1b|fig3|fig4>")?
        .clone();
    match which.as_str() {
        "table1" => table1(args),
        "table2" => table2(args),
        "table3" => table3(args),
        "table4" => table4(args),
        "table5" => table5(args),
        "table6" => table6(args),
        "fig1a" => fig1a(args),
        "fig1b" => fig1b(args),
        "fig3" => fig3(args),
        "fig4" => fig4(args),
        "all" => {
            for e in [
                "table1", "table2", "table3", "table4", "table5", "table6", "fig1a", "fig1b",
                "fig3", "fig4",
            ] {
                let mut argv = vec!["exp".to_string(), e.to_string()];
                argv.extend(raw_opts(args));
                dispatch(&Args::parse(argv)?)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment '{other}'"),
    }
}

fn raw_opts(args: &Args) -> Vec<String> {
    // carry common options through to sub-experiments
    let mut out = Vec::new();
    for k in
        ["configs", "config", "backend", "artifacts", "runs", "eval-batches", "calib-seqs", "epochs"]
    {
        if let Some(v) = args.get(k) {
            out.push(format!("--{k}={v}"));
        }
    }
    out
}

fn save_result(name: &str, payload: Json) -> Result<()> {
    std::fs::create_dir_all("results")?;
    let path = format!("results/{name}.json");
    std::fs::write(&path, payload.to_string_pretty())?;
    println!("[results -> {path}]");
    Ok(())
}

fn eval_batches(args: &Args) -> Result<usize> {
    args.usize_or("eval-batches", 12)
}

/// Prune a fresh copy of the dense checkpoint and measure ppl on all domains.
fn prune_and_eval(
    args: &Args,
    engine: &crate::runtime::Engine,
    dense: &ParamStore,
    method: Method,
    sparsity: f64,
) -> Result<(Vec<(String, f64)>, crate::coordinator::PruneRun, ParamStore)> {
    let mut p = dense.clone();
    let run = runs::prune_with(engine, &mut p, method, sparsity, args)?;
    let ppl = perplexity_all(engine, &p, eval_batches(args)?, 77)?;
    Ok((ppl, run, p))
}

// ---------------------------------------------------------------------------
// Table 1: perplexity @ 50% unstructured sparsity, 3 datasets x model family
// ---------------------------------------------------------------------------
pub fn table1(args: &Args) -> Result<()> {
    let configs = args.list_or("configs", &["sm", "md"]);
    let sparsity = args.f64_or("sparsity", 0.5)?;
    let methods = [Method::Dense, Method::SparseGpt, Method::Wanda, Method::Besa];
    println!("\n== Table 1: perplexity, unstructured {:.0}% sparsity ==", sparsity * 100.0);
    let mut rows: Vec<Json> = Vec::new();
    // dataset-major like the paper
    let mut per_cfg: BTreeMap<String, BTreeMap<&str, Vec<(String, f64)>>> = BTreeMap::new();
    for config in &configs {
        let engine = runs::engine_for(args, config)?;
        let dense = runs::load_params(args, &engine)?;
        for m in methods {
            let ppl = if m == Method::Dense {
                perplexity_all(&engine, &dense, eval_batches(args)?, 77)?
            } else {
                prune_and_eval(args, &engine, &dense, m, sparsity)?.0
            };
            for (d, v) in &ppl {
                rows.push(json::obj(vec![
                    ("config", json::s(config)),
                    ("method", json::s(m.name())),
                    ("dataset", json::s(d)),
                    ("ppl", json::num(*v)),
                ]));
            }
            per_cfg.entry(config.clone()).or_default().insert(m.name(), ppl);
        }
    }
    for dataset in ["wiki-syn", "c4-syn", "ptb-syn"] {
        println!("\n  dataset {dataset}:");
        print!("  {:<10}", "method");
        for c in &configs {
            print!(" {c:>10}");
        }
        println!();
        for m in methods {
            print!("  {:<10}", m.name());
            for c in &configs {
                let v = per_cfg[c][m.name()]
                    .iter()
                    .find(|(d, _)| d == dataset)
                    .map(|(_, v)| *v)
                    .unwrap_or(f64::NAN);
                print!(" {v:>10.4}");
            }
            println!();
        }
    }
    save_result("table1", Json::Arr(rows))
}

// ---------------------------------------------------------------------------
// Table 2: zero-shot probe accuracy
// ---------------------------------------------------------------------------
pub fn table2(args: &Args) -> Result<()> {
    let configs = args.list_or("configs", &["sm", "md"]);
    let sparsity = args.f64_or("sparsity", 0.5)?;
    let n_items = args.usize_or("items", 40)?;
    let methods = [Method::Dense, Method::SparseGpt, Method::Wanda, Method::Besa];
    println!("\n== Table 2: zero-shot probe accuracy (%) @ {:.0}% sparsity ==", sparsity * 100.0);
    let mut rows = Vec::new();
    for config in &configs {
        let engine = runs::engine_for(args, config)?;
        let dense = runs::load_params(args, &engine)?;
        println!("\n  model {config}:");
        println!(
            "  {:<10} {:>10} {:>10} {:>10} {:>8} {:>10} {:>8} {:>8}",
            "method", "wiki-cloze", "c4-cloze", "ptb-cloze", "copy", "retrieval", "numeric", "avg"
        );
        for m in methods {
            let params = if m == Method::Dense {
                dense.clone()
            } else {
                let mut p = dense.clone();
                runs::prune_with(&engine, &mut p, m, sparsity, args)?;
                p
            };
            let res = crate::eval::probes::run_all(&engine, &params, n_items, 99)?;
            print!("  {:<10}", m.name());
            for r in &res {
                print!(" {:>8.1}{}", r.accuracy * 100.0, if r.task == "numeric" { " " } else { " " });
                rows.push(json::obj(vec![
                    ("config", json::s(config)),
                    ("method", json::s(m.name())),
                    ("task", json::s(&r.task)),
                    ("accuracy", json::num(r.accuracy)),
                ]));
            }
            println!();
        }
    }
    save_result("table2", Json::Arr(rows))
}

// ---------------------------------------------------------------------------
// Table 3: joint pruning + 4-bit quantization
// ---------------------------------------------------------------------------
pub fn table3(args: &Args) -> Result<()> {
    let configs = args.list_or("configs", &["sm", "md"]);
    let sparsity = args.f64_or("sparsity", 0.5)?;
    println!("\n== Table 3: joint 4-bit quantization + {:.0}% pruning ==", sparsity * 100.0);
    let mut rows = Vec::new();
    println!(
        "  {:<6} {:<9} {:>10} {:>10} {:>10}",
        "model", "variant", "wiki-syn", "c4-syn", "ptb-syn"
    );
    for config in &configs {
        let engine = runs::engine_for(args, config)?;
        let dense = runs::load_params(args, &engine)?;
        let nb = eval_batches(args)?;

        let dense_ppl = perplexity_all(&engine, &dense, nb, 77)?;

        // Joint: BESA with learnable clipping (besa_quant_step artifact)
        let mut joint = dense.clone();
        {
            let calib = runs::calibration(args, &engine)?;
            let pipeline = crate::coordinator::Pipeline::new(&engine, calib.batches);
            let mut cfg = runs::besa_config(sparsity, args)?;
            cfg.quant = true;
            let mut pruner = BesaPruner::new(cfg);
            pipeline.run(&mut joint, &mut pruner)?;
        }
        let joint_ppl = perplexity_all(&engine, &joint, nb, 77)?;

        // Joint-Wanda baseline: quantize first (gamma = 1), then Wanda
        let mut jw = dense.clone();
        crate::quant::quantize_model(&mut jw, engine.config(), crate::quant::QuantSpec::default())?;
        runs::prune_with(&engine, &mut jw, Method::Wanda, sparsity, args)?;
        let jw_ppl = perplexity_all(&engine, &jw, nb, 77)?;

        for (name, ppl) in
            [("dense", &dense_ppl), ("joint", &joint_ppl), ("joint-wanda", &jw_ppl)]
        {
            print!("  {:<6} {:<9}", config, name);
            for (_, v) in ppl {
                print!(" {v:>10.4}");
            }
            println!();
            for (d, v) in ppl {
                rows.push(json::obj(vec![
                    ("config", json::s(config)),
                    ("variant", json::s(name)),
                    ("dataset", json::s(d)),
                    ("ppl", json::num(*v)),
                ]));
            }
        }
    }
    save_result("table3", Json::Arr(rows))
}

// ---------------------------------------------------------------------------
// Table 4: ViTCoD cycles + speedup per layer shape
// ---------------------------------------------------------------------------
pub fn table4(args: &Args) -> Result<()> {
    let config = args.str_or("config", "md");
    let sparsity = args.f64_or("sparsity", 0.5)?;
    let engine = runs::engine_for(args, &config)?;
    let dense = runs::load_params(args, &engine)?;
    let cfg = engine.config().clone();
    let sim = crate::sim::SimConfig { tokens: cfg.seq_len, ..Default::default() };

    println!("\n== Table 4: ViTCoD runtime (cycles) per layer, model {config} ==");
    let mut variants: Vec<(String, ParamStore)> = vec![];
    for m in [Method::SparseGpt, Method::Wanda, Method::Besa] {
        let mut p = dense.clone();
        runs::prune_with(&engine, &mut p, m, sparsity, args)?;
        variants.push((m.name().to_string(), p));
    }

    let layer_names = crate::model::LAYER_NAMES;
    print!("  {:<24}", "row");
    for l in layer_names {
        print!(" {l:>10}");
    }
    println!();
    let mut rows = Vec::new();

    // dense runtime row
    let dense_sims = crate::sim::simulate_block(&dense, &cfg, &sim)?;
    print!("  {:<24}", "dense runtime");
    for s in &dense_sims {
        print!(" {:>10}", s.dense_cycles);
    }
    println!();

    for (name, p) in &variants {
        let sims = crate::sim::simulate_block(p, &cfg, &sim)?;
        print!("  {:<24}", format!("avg runtime ({name})"));
        for s in &sims {
            print!(" {:>10}", s.sparse_cycles);
            rows.push(json::obj(vec![
                ("method", json::s(name)),
                ("layer", json::s(&s.layer)),
                ("cycles", json::num(s.sparse_cycles as f64)),
                ("dense_cycles", json::num(s.dense_cycles as f64)),
                ("sparsity", json::num(s.sparsity)),
                ("speedup", json::num(s.speedup)),
            ]));
        }
        println!();
    }
    // BESA per-layer sparsity + speedup (the paper's last two rows)
    let besa = &variants.last().unwrap().1;
    let sims = crate::sim::simulate_block(besa, &cfg, &sim)?;
    print!("  {:<24}", "BESA sparsity");
    for s in &sims {
        print!(" {:>9.2}%", s.sparsity * 100.0);
    }
    println!();
    print!("  {:<24}", "BESA speedup");
    for s in &sims {
        print!(" {:>9.2}x", s.speedup);
    }
    println!();
    save_result("table4", Json::Arr(rows))
}

// ---------------------------------------------------------------------------
// Table 5: ablations — epochs, sparsity step (D), importance metric
// ---------------------------------------------------------------------------
pub fn table5(args: &Args) -> Result<()> {
    let config = args.str_or("config", "sm");
    let engine = runs::engine_for(args, &config)?;
    let dense = runs::load_params(args, &engine)?;
    let nb = eval_batches(args)?;
    let mut rows = Vec::new();

    println!("\n== Table 5a: epochs ablation (model {config}) ==");
    println!("  {:<8} {:>10} {:>10} {:>10}", "epochs", "wiki-syn", "c4-syn", "ptb-syn");
    for epochs in [4usize, 12, 24, 48] {
        let mut p = dense.clone();
        let calib = runs::calibration(args, &engine)?;
        let pipeline = crate::coordinator::Pipeline::new(&engine, calib.batches);
        let mut bc = runs::besa_config(0.5, args)?;
        bc.epochs = epochs;
        let mut pruner = BesaPruner::new(bc);
        pipeline.run(&mut p, &mut pruner)?;
        let ppl = perplexity_all(&engine, &p, nb, 77)?;
        print!("  {epochs:<8}");
        for (d, v) in &ppl {
            print!(" {v:>10.4}");
            rows.push(json::obj(vec![
                ("ablation", json::s("epochs")),
                ("value", json::num(epochs as f64)),
                ("dataset", json::s(d)),
                ("ppl", json::num(*v)),
            ]));
        }
        println!();
    }

    println!("\n== Table 5b: sparsity-step (candidate-rate count D) ablation ==");
    println!("  {:<8} {:>10} {:>10} {:>10}", "D", "wiki-syn", "c4-syn", "ptb-syn");
    let mut dvals = vec![engine.config().n_rates];
    for alt in alt_rate_artifacts(&engine) {
        dvals.push(alt);
    }
    dvals.sort();
    dvals.dedup();
    for d in dvals {
        let mut p = dense.clone();
        let calib = runs::calibration(args, &engine)?;
        let pipeline = crate::coordinator::Pipeline::new(&engine, calib.batches);
        let mut bc = runs::besa_config(0.5, args)?;
        let mut pruner = BesaPruner::new(bc.clone());
        if d != engine.config().n_rates {
            pruner = BesaPruner::new(bc);
            pruner.rate_override = Some(d);
        }
        pipeline.run(&mut p, &mut pruner)?;
        let ppl = perplexity_all(&engine, &p, nb, 77)?;
        print!("  {d:<8}");
        for (ds, v) in &ppl {
            print!(" {v:>10.4}");
            rows.push(json::obj(vec![
                ("ablation", json::s("n_rates")),
                ("value", json::num(d as f64)),
                ("dataset", json::s(ds)),
                ("ppl", json::num(*v)),
            ]));
        }
        println!();
    }

    println!("\n== Table 5c: importance-metric ablation ==");
    println!("  {:<10} {:>10} {:>10} {:>10}", "metric", "wiki-syn", "c4-syn", "ptb-syn");
    for (name, metric) in [
        ("weight", Metric::WeightMagnitude),
        ("wanda", Metric::Wanda),
        ("sparsegpt", Metric::SparseGpt),
    ] {
        let mut p = dense.clone();
        let calib = runs::calibration(args, &engine)?;
        let pipeline = crate::coordinator::Pipeline::new(&engine, calib.batches);
        let mut bc = runs::besa_config(0.5, args)?;
        bc.metric = metric;
        let mut pruner = BesaPruner::new(bc);
        pipeline.run(&mut p, &mut pruner)?;
        let ppl = perplexity_all(&engine, &p, nb, 77)?;
        print!("  {name:<10}");
        for (ds, v) in &ppl {
            print!(" {v:>10.4}");
            rows.push(json::obj(vec![
                ("ablation", json::s("metric")),
                ("value", json::s(name)),
                ("dataset", json::s(ds)),
                ("ppl", json::num(*v)),
            ]));
        }
        println!();
    }
    save_result("table5", Json::Arr(rows))
}

fn alt_rate_artifacts(engine: &crate::runtime::Engine) -> Vec<usize> {
    // populated by both Manifest::load and Manifest::synthesize
    engine.config().alt_rates.clone()
}

// ---------------------------------------------------------------------------
// Table 6 + Fig 5: learning granularity
// ---------------------------------------------------------------------------
pub fn table6(args: &Args) -> Result<()> {
    let config = args.str_or("config", "sm");
    let engine = runs::engine_for(args, &config)?;
    let dense = runs::load_params(args, &engine)?;
    let nb = eval_batches(args)?;
    let mut rows = Vec::new();
    println!("\n== Table 6: learning granularity (model {config}) ==");
    println!(
        "  {:<14} {:>10} {:>10} {:>10}   block recon errors (Fig 5)",
        "granularity", "wiki-syn", "c4-syn", "ptb-syn"
    );

    let mut run_one = |name: &str,
                       ppl: Vec<(String, f64)>,
                       errs: Vec<f64>,
                       rows: &mut Vec<Json>|
     -> Result<()> {
        print!("  {name:<14}");
        for (_, v) in &ppl {
            print!(" {v:>10.4}");
        }
        print!("   [");
        for e in &errs {
            print!("{e:.2e} ");
        }
        println!("]");
        rows.push(json::obj(vec![
            ("granularity", json::s(name)),
            (
                "ppl",
                Json::Arr(ppl.iter().map(|(d, v)| {
                    json::obj(vec![("dataset", json::s(d)), ("ppl", json::num(*v))])
                }).collect()),
            ),
            ("block_errors", Json::Arr(errs.iter().map(|e| json::num(*e)).collect())),
        ]));
        Ok(())
    };

    // layer == Wanda
    let (ppl, run, _) = prune_and_eval(args, &engine, &dense, Method::Wanda, 0.5)?;
    run_one("layer (wanda)", ppl, run.block_errors, &mut rows)?;

    // attn-mlp
    {
        let mut p = dense.clone();
        let calib = runs::calibration(args, &engine)?;
        let pipeline = crate::coordinator::Pipeline::new(&engine, calib.batches);
        let mut bc = runs::besa_config(0.5, args)?;
        bc.granularity = crate::prune::besa::Granularity::AttnMlp;
        let mut pruner = BesaPruner::new(bc);
        let run = pipeline.run(&mut p, &mut pruner)?;
        let ppl = perplexity_all(&engine, &p, nb, 77)?;
        run_one("attn-mlp", ppl, run.block_errors, &mut rows)?;
    }

    // block (BESA default)
    let (ppl, run, _) = prune_and_eval(args, &engine, &dense, Method::Besa, 0.5)?;
    run_one("block (besa)", ppl, run.block_errors, &mut rows)?;

    // two blocks
    {
        let mut p = dense.clone();
        let calib = runs::calibration(args, &engine)?;
        let bc = runs::besa_config(0.5, args)?;
        let (_, errs) =
            crate::prune::besa::two_block_prune(&engine, &mut p, &calib.batches, &bc)?;
        let ppl = perplexity_all(&engine, &p, nb, 77)?;
        run_one("two blocks", ppl, errs, &mut rows)?;
    }
    save_result("table6", Json::Arr(rows))
}

// ---------------------------------------------------------------------------
// Fig 1a: blockwise error accumulation, layerwise (wanda) vs BESA
// ---------------------------------------------------------------------------
pub fn fig1a(args: &Args) -> Result<()> {
    let config = args.str_or("config", "sm");
    let engine = runs::engine_for(args, &config)?;
    let dense = runs::load_params(args, &engine)?;
    println!("\n== Fig 1a: relative output error after each pruned block ==");
    let mut rows = Vec::new();
    for m in [Method::Wanda, Method::Besa] {
        let (_, run, _) = prune_and_eval(args, &engine, &dense, m, 0.5)?;
        print!("  {:<10}", m.name());
        for e in &run.block_errors {
            print!(" {e:>10.3e}");
        }
        println!();
        rows.push(json::obj(vec![
            ("method", json::s(m.name())),
            ("block_errors", Json::Arr(run.block_errors.iter().map(|e| json::num(*e)).collect())),
        ]));
    }
    save_result("fig1a", Json::Arr(rows))
}

// ---------------------------------------------------------------------------
// Fig 1b: layers tolerate sparsity unequally — prune a single block at
// varying sparsity and track wiki-syn ppl
// ---------------------------------------------------------------------------
pub fn fig1b(args: &Args) -> Result<()> {
    let config = args.str_or("config", "sm");
    let engine = runs::engine_for(args, &config)?;
    let dense = runs::load_params(args, &engine)?;
    let cfg = engine.config().clone();
    let nb = eval_batches(args)?;
    let sweep = [0.25, 0.5, 0.75, 0.9];
    println!("\n== Fig 1b: wiki-syn ppl vs single-block sparsity ==");
    print!("  {:<8}", "block");
    for s in sweep {
        print!(" {:>9.0}%", s * 100.0);
    }
    println!();
    let mut rows = Vec::new();
    for l in 0..cfg.n_blocks {
        print!("  {l:<8}");
        let mut series = Vec::new();
        for s in sweep {
            let mut p = dense.clone();
            // magnitude-prune only block l (cheap, no pipeline needed)
            for w in crate::model::LAYER_NAMES {
                let name = ParamStore::layer_name(l, w);
                let t = p.get(&name)?.clone();
                let mask = crate::prune::topk_row_mask(
                    &crate::prune::importance::magnitude_scores(&t),
                    s,
                );
                let mut t2 = t;
                for (v, m) in t2.f32s_mut().iter_mut().zip(mask.f32s()) {
                    *v *= m;
                }
                p.set(&name, t2)?;
            }
            let ppl = perplexity(&engine, &p, crate::data::Domain::WikiSyn, nb, 77)?;
            print!(" {ppl:>10.4}");
            series.push(json::num(ppl));
        }
        println!();
        rows.push(json::obj(vec![("block", json::num(l as f64)), ("ppl", Json::Arr(series))]));
    }
    save_result("fig1b", Json::Arr(rows))
}

// ---------------------------------------------------------------------------
// Fig 3: ppl vs model sparsity (methods)
// ---------------------------------------------------------------------------
pub fn fig3(args: &Args) -> Result<()> {
    let config = args.str_or("config", "sm");
    let engine = runs::engine_for(args, &config)?;
    let dense = runs::load_params(args, &engine)?;
    let sweep = [0.125, 0.25, 0.375, 0.5, 0.625, 0.75];
    println!("\n== Fig 3: wiki-syn ppl vs sparsity (model {config}) ==");
    print!("  {:<10}", "method");
    for s in sweep {
        print!(" {:>9.1}%", s * 100.0);
    }
    println!();
    let nb = eval_batches(args)?;
    let mut rows = Vec::new();
    for m in [Method::Magnitude, Method::SparseGpt, Method::Wanda, Method::Besa] {
        print!("  {:<10}", m.name());
        let mut series = Vec::new();
        for s in sweep {
            let mut p = dense.clone();
            runs::prune_with(&engine, &mut p, m, s, args)?;
            let ppl = perplexity(&engine, &p, crate::data::Domain::WikiSyn, nb, 77)?;
            print!(" {ppl:>10.4}");
            series.push(json::num(ppl));
        }
        println!();
        rows.push(json::obj(vec![("method", json::s(m.name())), ("ppl", Json::Arr(series))]));
    }
    save_result("fig3", Json::Arr(rows))
}

// ---------------------------------------------------------------------------
// Fig 4: calibration-size ablation
// ---------------------------------------------------------------------------
pub fn fig4(args: &Args) -> Result<()> {
    let config = args.str_or("config", "sm");
    let engine = runs::engine_for(args, &config)?;
    let dense = runs::load_params(args, &engine)?;
    let cfg = engine.config().clone();
    let nb = eval_batches(args)?;
    let sizes: Vec<usize> =
        [1usize, 2, 4, 8, 16].iter().map(|k| k * cfg.batch).collect();
    println!("\n== Fig 4: wiki-syn ppl vs calibration size (model {config}) ==");
    println!("  {:<10} {:>10}", "calib seqs", "ppl");
    let mut rows = Vec::new();
    for n in sizes {
        let mut p = dense.clone();
        let calib = crate::data::batcher::CalibrationSet::sample(&cfg, n, 0xCA11B);
        let pipeline = crate::coordinator::Pipeline::new(&engine, calib.batches);
        let mut pruner = BesaPruner::new(runs::besa_config(0.5, args)?);
        pipeline.run(&mut p, &mut pruner)?;
        let ppl = perplexity(&engine, &p, crate::data::Domain::WikiSyn, nb, 77)?;
        println!("  {n:<10} {ppl:>10.4}");
        rows.push(json::obj(vec![("calib_seqs", json::num(n as f64)), ("ppl", json::num(ppl))]));
    }
    save_result("fig4", Json::Arr(rows))
}
