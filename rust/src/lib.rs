//! # BESA — Blockwise Parameter-Efficient Sparsity Allocation
//!
//! A production-grade Rust reproduction of *“BESA: Pruning Large Language
//! Models with Blockwise Parameter-Efficient Sparsity Allocation”*
//! (Xu et al., ICLR 2024), built as a three-layer stack:
//!
//! * **L1/L2 (build time)** — Pallas kernels + JAX graphs under `python/`,
//!   AOT-lowered once to HLO text artifacts (`make artifacts`).
//! * **L3 (this crate)** — the coordinator: loads the artifacts through the
//!   PJRT C API ([`runtime`]), owns the sequential block-by-block pruning
//!   pipeline (paper Algorithm 1) in [`coordinator`] and [`prune`], the
//!   pruning baselines (magnitude / Wanda / SparseGPT), joint
//!   quantization ([`quant`]), evaluation harnesses ([`eval`]), the
//!   synthetic-corpus data substrate ([`data`]) and the ViTCoD
//!   accelerator cycle simulator ([`sim`], paper §4.5 + Appendix B).
//!
//! Python never runs after artifact generation: the `besa` binary is
//! self-contained.

pub mod cli;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod linalg;
pub mod model;
pub mod prune;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod util;

pub use anyhow::{bail, Context, Result};
