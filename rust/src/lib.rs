//! # BESA — Blockwise Parameter-Efficient Sparsity Allocation
//!
//! A production-grade Rust reproduction of *“BESA: Pruning Large Language
//! Models with Blockwise Parameter-Efficient Sparsity Allocation”*
//! (Xu et al., ICLR 2024), built around a pluggable execution runtime:
//!
//! * **[`runtime`]** — the [`runtime::Backend`] trait behind the
//!   [`runtime::Engine`] facade, with two implementations:
//!   * `native` (default): a pure-Rust interpreter of the full artifact op
//!     set (`embed`, `block_fwd*`, `besa_step*`, `two_block_step`,
//!     `lm_train_step`, `head_nll`, mask/quant helpers) on the [`tensor`] /
//!     [`linalg`] substrate, with specs synthesized from the built-in
//!     config table. **Hermetic**: `cargo build && cargo test` need no
//!     artifacts, no Python, no XLA — this is the guarantee the test
//!     suite runs under.
//!   * `pjrt` (cargo feature `pjrt`): Pallas/JAX graphs under `python/`
//!     AOT-lowered once to HLO text (`make artifacts`), compiled and
//!     executed through the PJRT C API. The workspace vendors an API stub
//!     of the `xla` bindings so the feature always typechecks offline;
//!     point `vendor/xla` at the real crate to execute.
//!
//!   Select with `--backend native|pjrt` (CLI) or `BESA_BACKEND` (env).
//!
//! * **[`coordinator`]** — the block-sequential pruning pipeline (paper
//!   Algorithm 1) plus the LM pretraining driver. Per-minibatch loops
//!   (dense-target forward, capture pass, path advance) dispatch
//!   batch-parallel across scoped threads: `Engine` is `Sync`.
//! * **[`prune`]** — BESA itself plus the magnitude / Wanda / SparseGPT
//!   baselines; [`quant`] for joint 4-bit quantization (paper §3.3);
//!   [`eval`] for perplexity + zero-shot probes; [`data`] for the
//!   synthetic corpus; [`sim`] for the ViTCoD accelerator cycle model
//!   (paper §4.5 + Appendix B).
//! * **[`kernel`]** — the shared microkernel layer every dense, sparse
//!   and attention inner loop routes through: cache-tiled / packed GEMM,
//!   register-blocked SpMM stripes, attention score/value lanes and the
//!   fused RMSNorm+matvec decode path. Each kernel ships a scalar
//!   reference and a register-blocked micro variant (`BESA_KERNEL=
//!   scalar|micro`, default micro) that are bitwise identical by
//!   construction; `besa kernel-bench` measures both into
//!   `BENCH_kernels.json` (see `docs/kernels.md`).
//! * **[`sparse`] + [`serve`]** — where the sparsity pays off: packed
//!   CSR / quantized-CSR weights with one row-blocked SpMM kernel
//!   (value-accessor parameterized), and an inference engine
//!   (continuous-batching scheduler, per-request KV caches,
//!   O(1)-per-token decode via the native `block_fwd_cached` op) behind
//!   `besa serve-bench` — offline trace replay per weight format, plus
//!   the online mode (`--async`): wall-clock request ingestion
//!   (Poisson / bursty / closed-loop) into a sharded multi-worker pool
//!   with per-worker continuous batching and a queue-wait vs compute
//!   metrics split. [`serve::net`] puts a real TCP front end on the same
//!   engine (line-delimited JSON + an HTTP/1.1-subset adapter, `besa
//!   serve-net`) with overload control: per-client token buckets,
//!   deadline shedding, bounded-queue backpressure, FIFO / priority /
//!   EDF queue policies and graceful drain (see `docs/serving.md`).
//! * **[`telemetry`]** — per-request span timing (accept / parse / queue
//!   / admit / prefill / decode / serialize) buffered per worker and
//!   dumped as JSONL via `--trace-out` (label discipline in
//!   `docs/telemetry.md`).
//!
//! Cross-backend correctness is pinned by `tests/native_parity.rs`:
//! golden vectors generated from a float64 reference transliteration of
//! the python graphs (`python/tools/gen_golden.py`), finite-difference
//! gradient checks, and structural invariants (causality, STE broadcast
//! consistency, mask-decode bit-parity).

// Numeric-kernel style: explicit index loops mirror the math in the paper
// and the python reference implementation; the iterator rewrites clippy
// suggests obscure the row/column structure.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::type_complexity)]

pub mod analyze;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod kernel;
pub mod linalg;
pub mod model;
pub mod prune;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod sparse;
pub mod telemetry;
pub mod tensor;
pub mod util;

pub use anyhow::{bail, Context, Result};
