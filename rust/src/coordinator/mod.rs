//! The block-sequential pruning pipeline (paper Algorithm 1).
//!
//! Maintains two activation streams over the calibration set — the
//! full-precision path `X_fp` (through dense blocks) and the pruned path
//! `X_p` (through already-pruned blocks) — prunes one transformer block at
//! a time, then advances both streams. This is what bounds GPU/host memory
//! in the paper and lets a 7B-180B model prune on one device; here it
//! bounds host memory and keeps every executable shape-static.
//!
//! The per-minibatch loops (dense-target forward, capture pass, path
//! advance) fan out across scoped threads via [`crate::util::par`]: the
//! [`Engine`] facade is `Sync`, so calibration minibatches execute
//! batch-parallel against one shared backend.

pub mod trainer;

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::model::{ModelConfig, ParamStore, LAYER_NAMES};
use crate::prune::importance::ColNorms;
use crate::prune::{BlockMasks, BlockReport};
use crate::runtime::Engine;
use crate::tensor::Tensor;
use crate::util::par::par_map;
use crate::util::Stopwatch;

/// Everything a block pruner may consume for one block.
pub struct BlockCtx<'a> {
    pub engine: &'a Engine,
    pub cfg: &'a ModelConfig,
    pub block: usize,
    /// the seven prunable weights (cloned, mutable by SparseGPT updates)
    pub weights: BTreeMap<String, Tensor>,
    pub norms: [Tensor; 2],
    /// pruned-path inputs, one [B,S,d] per calibration minibatch
    pub x_pruned: &'a [Tensor],
    /// dense targets F(W, X_fp), one per minibatch (Algorithm 1 line 3)
    pub y_dense: &'a [Tensor],
    /// streaming column norms of the layer inputs (pruned path)
    pub colnorms: ColNorms,
    /// gram matrices X^T X of the layer inputs, keyed by capture point
    /// ("h1", "att", "h2", "act"); present only when `need_hessian`
    pub hessians: BTreeMap<String, crate::linalg::Mat>,
}

impl<'a> BlockCtx<'a> {
    pub fn weight(&self, layer: &str) -> &Tensor {
        &self.weights[layer]
    }

    pub fn hessian_for(&self, layer: &str) -> &crate::linalg::Mat {
        let key = match layer {
            "wq" | "wk" | "wv" => "h1",
            "wo" => "att",
            "wg" | "wu" => "h2",
            "wd" => "act",
            other => panic!("unknown layer {other}"),
        };
        &self.hessians[key]
    }
}

/// A pruning algorithm plugged into the pipeline.
pub trait BlockPruner {
    fn name(&self) -> &str;
    /// Whether the pipeline must accumulate input gram matrices.
    fn needs_hessian(&self) -> bool {
        false
    }
    /// Produce 0/1 masks for the block. May also update `ctx.weights`
    /// in place (SparseGPT's OBS reconstruction does).
    fn prune_block(&mut self, ctx: &mut BlockCtx) -> Result<(BlockMasks, BlockReport)>;
}

/// Result of a full pipeline run.
pub struct PruneRun {
    pub reports: Vec<BlockReport>,
    /// relative blockwise output error ||X_p - X_fp||^2 / ||X_fp||^2 after
    /// each pruned block (Fig. 1a series)
    pub block_errors: Vec<f64>,
    pub masks: Vec<BlockMasks>,
    pub secs: f64,
}

pub struct Pipeline<'a> {
    pub engine: &'a Engine,
    pub calib: Vec<Tensor>,
}

impl<'a> Pipeline<'a> {
    pub fn new(engine: &'a Engine, calib: Vec<Tensor>) -> Pipeline<'a> {
        Pipeline { engine, calib }
    }

    /// Embed all calibration batches: the starting activations of both paths.
    fn embed_all(&self, params: &ParamStore) -> Result<Vec<Tensor>> {
        let emb = params.get("embed")?;
        par_map(&self.calib, |toks| {
            let out = self.engine.run("embed", &[toks, emb])?;
            Ok(out.into_iter().next().unwrap())
        })
    }

    /// `block_fwd` over every minibatch in `xs`, batch-parallel.
    fn block_fwd_all(
        &self,
        xs: &[Tensor],
        weights: &[&Tensor],
        norms: [&Tensor; 2],
    ) -> Result<Vec<Tensor>> {
        par_map(xs, |x| {
            let mut ins: Vec<&Tensor> = vec![x];
            ins.extend(weights.iter().copied());
            ins.push(norms[0]);
            ins.push(norms[1]);
            let out = self.engine.run("block_fwd", &ins)?;
            Ok(out.into_iter().next().unwrap())
        })
    }

    /// Run Algorithm 1: prune every block of `params` in place with `pruner`.
    pub fn run(&self, params: &mut ParamStore, pruner: &mut dyn BlockPruner) -> Result<PruneRun> {
        let cfg = self.engine.config().clone();
        let sw = Stopwatch::start();
        let mut x_fp = self.embed_all(params)?;
        let mut x_p = x_fp.clone();
        let mut reports = Vec::new();
        let mut block_errors = Vec::new();
        let mut all_masks = Vec::new();

        for l in 0..cfg.n_blocks {
            let bsw = Stopwatch::start();
            // ---- gather block inputs -------------------------------------
            let weights: BTreeMap<String, Tensor> = LAYER_NAMES
                .iter()
                .map(|w| {
                    ((*w).to_string(), params.get(&ParamStore::layer_name(l, w)).unwrap().clone())
                })
                .collect();
            let norms = [
                params.get(&format!("blocks.{l}.norm1"))?.clone(),
                params.get(&format!("blocks.{l}.norm2"))?.clone(),
            ];
            let weight_refs: Vec<&Tensor> = LAYER_NAMES.iter().map(|w| &weights[*w]).collect();

            // dense targets on the dense path (batch-parallel)
            let y_dense = self.block_fwd_all(&x_fp, &weight_refs, [&norms[0], &norms[1]])?;

            // captures on the pruned path: batch-parallel inside a bounded
            // window, folding the streaming statistics in deterministic
            // minibatch order after each window. The window keeps peak
            // capture memory at O(workers) minibatches, not O(calib set) —
            // the memory-bounding property of the block-sequential design.
            let mut colnorms = ColNorms::new(&cfg);
            let mut hessians: BTreeMap<String, crate::linalg::Mat> = BTreeMap::new();
            if pruner.needs_hessian() {
                hessians.insert("h1".into(), crate::linalg::Mat::zeros(cfg.d_model, cfg.d_model));
                hessians.insert("att".into(), crate::linalg::Mat::zeros(cfg.d_model, cfg.d_model));
                hessians.insert("h2".into(), crate::linalg::Mat::zeros(cfg.d_model, cfg.d_model));
                hessians.insert("act".into(), crate::linalg::Mat::zeros(cfg.d_ffn, cfg.d_ffn));
            }
            let window = crate::util::par::workers_for(x_p.len()).max(1);
            for chunk in x_p.chunks(window) {
                let captures = par_map(chunk, |x| {
                    let mut ins: Vec<&Tensor> = vec![x];
                    ins.extend(weight_refs.iter().copied());
                    ins.push(&norms[0]);
                    ins.push(&norms[1]);
                    self.engine.run("block_capture", &ins)
                })?;
                for out in &captures {
                    // outputs: y, h1, att, h2, act
                    colnorms.accumulate(&out[1], &out[2], &out[3], &out[4]);
                    if pruner.needs_hessian() {
                        let toks = cfg.tokens_per_batch();
                        hessians.get_mut("h1").unwrap().add_gram_f32(out[1].f32s(), toks);
                        hessians.get_mut("att").unwrap().add_gram_f32(out[2].f32s(), toks);
                        hessians.get_mut("h2").unwrap().add_gram_f32(out[3].f32s(), toks);
                        hessians.get_mut("act").unwrap().add_gram_f32(out[4].f32s(), toks);
                    }
                }
            }

            // ---- prune ---------------------------------------------------
            let mut ctx = BlockCtx {
                engine: self.engine,
                cfg: &cfg,
                block: l,
                weights,
                norms,
                x_pruned: &x_p,
                y_dense: &y_dense,
                colnorms,
                hessians,
            };
            let (masks, mut report) = pruner.prune_block(&mut ctx)?;
            report.block = l;

            // ---- apply masks (and any OBS weight updates) -----------------
            for w in LAYER_NAMES {
                let name = ParamStore::layer_name(l, w);
                let mut t = ctx.weights.remove(w).context("weight consumed twice")?;
                let m =
                    masks.get(w).with_context(|| format!("pruner returned no mask for {w}"))?;
                for (v, mv) in t.f32s_mut().iter_mut().zip(m.f32s()) {
                    *v *= mv;
                }
                params.set(&name, t)?;
            }

            // ---- advance both paths (batch-parallel) ----------------------
            let weights_now: Vec<&Tensor> = LAYER_NAMES
                .iter()
                .map(|w| params.get(&ParamStore::layer_name(l, w)).unwrap())
                .collect();
            let norms_now = [
                params.get(&format!("blocks.{l}.norm1"))?,
                params.get(&format!("blocks.{l}.norm2"))?,
            ];
            let advanced = self.block_fwd_all(&x_p, &weights_now, norms_now)?;
            let mut err_num = 0.0f64;
            let mut err_den = 0.0f64;
            for (i, y_p) in advanced.into_iter().enumerate() {
                let y_fp = &y_dense[i];
                for (a, b) in y_p.f32s().iter().zip(y_fp.f32s()) {
                    let d = (*a - *b) as f64;
                    err_num += d * d;
                    err_den += (*b as f64) * (*b as f64);
                }
                x_p[i] = y_p;
            }
            x_fp = y_dense;
            block_errors.push(err_num / err_den.max(1e-12));

            crate::info!(
                "block {l}/{}: {} sparsity={:.4} recon={:.3e} err_acc={:.3e} ({:.1}s)",
                cfg.n_blocks,
                pruner.name(),
                report.mean_sparsity(&cfg),
                report.recon_error,
                block_errors[l],
                bsw.secs()
            );
            reports.push(report);
            all_masks.push(masks);
        }

        Ok(PruneRun { reports, block_errors, masks: all_masks, secs: sw.secs() })
    }
}
