//! LM pretraining driver: Adam over all model parameters with gradients
//! from the AOT `lm_train_step` artifact. Produces the dense checkpoints
//! the pruning experiments start from (the repo's stand-in for downloading
//! LLaMA weights — DESIGN.md §Substitutions).

use anyhow::Result;

use crate::data::{Batcher, Domain};
use crate::model::ParamStore;
use crate::prune::adam::{Adam, AdamConfig};
use crate::runtime::Engine;
use crate::tensor::Tensor;
use crate::util::Stopwatch;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    /// mix all three domains (model must know every eval distribution)
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { steps: 300, lr: 3e-3, seed: 1234, log_every: 20 }
    }
}

pub struct TrainStats {
    pub losses: Vec<f64>,
    pub secs: f64,
    pub tokens_seen: usize,
}

/// Train in place; returns the loss curve. Domains are interleaved
/// round-robin so each step sees one domain's batch.
pub fn pretrain(engine: &Engine, params: &mut ParamStore, tc: &TrainConfig) -> Result<TrainStats> {
    let cfg = engine.config().clone();
    let mut batchers: Vec<Batcher> = Domain::all()
        .iter()
        .map(|d| Batcher::new(*d, tc.seed, &cfg))
        .collect();
    let n_params = cfg.param_order.len();
    let mut adam = Adam::new(AdamConfig { lr: tc.lr, ..Default::default() }, n_params);
    let sw = Stopwatch::start();
    let mut losses = Vec::with_capacity(tc.steps);

    let n_dom = batchers.len();
    for step in 0..tc.steps {
        let tokens = batchers[step % n_dom].next_batch();
        let mut ins: Vec<&Tensor> = params.ordered();
        ins.push(&tokens);
        let out = engine.run("lm_train_step", &ins)?;
        let loss = out[0].scalar_value() as f64;
        losses.push(loss);
        let grads: Vec<&Tensor> = out[1..].iter().collect();
        // Adam over the canonical order
        let order: Vec<String> = params.order().to_vec();
        let mut refs: Vec<*mut Tensor> = Vec::with_capacity(order.len());
        for name in &order {
            refs.push(params.get_mut(name)? as *mut Tensor);
        }
        // SAFETY: names are unique (BTreeMap keys), so the raw pointers
        // alias distinct tensors.
        let mut muts: Vec<&mut Tensor> =
            refs.into_iter().map(|p| unsafe { &mut *p }).collect();
        adam.step(&mut muts, &grads);

        if step % tc.log_every == 0 || step + 1 == tc.steps {
            crate::info!(
                "pretrain step {step}/{}: loss {loss:.4} ({:.1} tok/s)",
                tc.steps,
                ((step + 1) * cfg.tokens_per_batch()) as f64 / sw.secs()
            );
        }
    }
    Ok(TrainStats {
        losses,
        secs: sw.secs(),
        tokens_seen: tc.steps * cfg.tokens_per_batch(),
    })
}
