//! Leveled stderr logger with wall-clock timestamps relative to process
//! start. Controlled by `BESA_LOG` (error|warn|info|debug|trace) or the
//! `--log` CLI flag; default `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();

pub fn init_from_env() {
    if let Ok(v) = std::env::var("BESA_LOG") {
        set_level_str(&v);
    }
    START.get_or_init(Instant::now);
}

pub fn set_level_str(s: &str) {
    let lvl = match s.to_ascii_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    };
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warnlog {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debuglog {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        set_level_str("warn");
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level_str("info");
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
