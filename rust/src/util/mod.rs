//! Self-contained utility substrate.
//!
//! The build is fully offline (vendored crates only: `xla`, `anyhow`), so
//! the usual ecosystem pieces are implemented here from scratch: a JSON
//! parser/writer (artifact manifests, experiment results), a TOML-subset
//! config parser, a splitmix/xoshiro RNG, a CLI argument parser, a
//! micro-bench harness (criterion replacement) and a tiny property-based
//! testing helper (proptest replacement).

pub mod args;
pub mod bench;
pub mod json;
pub mod logging;
pub mod par;
pub mod proptest;
pub mod rng;
pub mod toml;

/// Wall-clock stopwatch used by the pipeline metrics and benches.
#[derive(Debug)]
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(std::time::Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len().max(1) as f64).sqrt()
}

/// Percentile via nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
    s[idx.min(s.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
        assert_eq!(percentile(&[3.0, 1.0, 2.0], 50.0), 2.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
