//! Tiny CLI argument parser (clap is not vendorable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args,
//! with typed getters and a generated usage string.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    present: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminates option parsing
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                    out.present.push(k.to_string());
                } else {
                    // value-taking if next token is not another option
                    let takes_value =
                        it.peek().map(|n| !n.starts_with("--")).unwrap_or(false);
                    if takes_value {
                        out.flags.insert(rest.to_string(), it.next().unwrap());
                    } else {
                        out.flags.insert(rest.to_string(), "true".to_string());
                    }
                    out.present.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn has(&self, key: &str) -> bool {
        self.present.iter().any(|k| k == key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects a float, got {v:?}")),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32> {
        Ok(self.f64_or(key, default as f64)? as f32)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn required(&self, key: &str) -> Result<&str> {
        match self.get(key) {
            Some(v) => Ok(v),
            None => bail!("missing required option --{key}"),
        }
    }

    /// Comma-separated list: `--configs sm,md,lg`.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(argv: &[&str]) -> Args {
        Args::parse(argv.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&["prune", "extra", "--config", "md", "--sparsity=0.5", "--verbose"]);
        assert_eq!(a.positional, vec!["prune", "extra"]);
        assert_eq!(a.get("config"), Some("md"));
        assert_eq!(a.f64_or("sparsity", 0.0).unwrap(), 0.5);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn flag_before_positional() {
        let a = parse(&["--fast", "run"]);
        // "run" is consumed as the value of --fast (documented limitation;
        // use --fast=true run, or put flags last)
        assert_eq!(a.get("fast"), Some("run"));
    }

    #[test]
    fn double_dash_stops() {
        let a = parse(&["--a", "1", "--", "--b"]);
        assert_eq!(a.positional, vec!["--b"]);
    }

    #[test]
    fn typed_errors() {
        let a = parse(&["--n", "abc"]);
        assert!(a.usize_or("n", 3).is_err());
        assert!(a.required("missing").is_err());
    }

    #[test]
    fn lists() {
        let a = parse(&["--configs", "sm, md,lg"]);
        assert_eq!(a.list_or("configs", &[]), vec!["sm", "md", "lg"]);
        assert_eq!(a.list_or("other", &["x"]), vec!["x"]);
    }
}
