//! TOML-subset parser for run configuration files.
//!
//! Supports: `[section]` / `[section.sub]` headers, `key = value` with
//! string / integer / float / bool / flat-array values, `#` comments.
//! Keys are exposed flattened as `section.sub.key`.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct Toml {
    pub entries: BTreeMap<String, Value>,
}

impl Toml {
    pub fn parse(src: &str) -> Result<Toml> {
        let mut out = Toml::default();
        let mut prefix = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(h) = line.strip_prefix('[') {
                let h = h
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?;
                prefix = h.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if prefix.is_empty() {
                k.trim().to_string()
            } else {
                format!("{}.{}", prefix, k.trim())
            };
            out.entries.insert(
                key,
                parse_value(v.trim())
                    .with_context(|| format!("line {}: bad value {:?}", lineno + 1, v.trim()))?,
            );
        }
        Ok(out)
    }

    pub fn load(path: &std::path::Path) -> Result<Toml> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&src)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_i64()).map(|v| v as usize).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings is preserved
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<Value> {
    if let Some(body) = v.strip_prefix('"') {
        let body = body.strip_suffix('"').context("unterminated string")?;
        return Ok(Value::Str(body.to_string()));
    }
    if v == "true" {
        return Ok(Value::Bool(true));
    }
    if v == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = v.strip_prefix('[') {
        let body = body.strip_suffix(']').context("unterminated array")?;
        let mut items = Vec::new();
        for part in body.split(',') {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Value::Arr(items));
    }
    if let Ok(i) = v.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("unparseable value")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let t = Toml::parse(
            r#"
            top = 1
            [prune]
            sparsity = 0.5      # target
            method = "besa"
            rowwise = true
            [prune.adam]
            lr = 1e-2
            steps = [1, 2, 3]
            "#,
        )
        .unwrap();
        assert_eq!(t.get("top").unwrap().as_i64(), Some(1));
        assert_eq!(t.f64_or("prune.sparsity", 0.0), 0.5);
        assert_eq!(t.str_or("prune.method", ""), "besa");
        assert!(t.bool_or("prune.rowwise", false));
        assert_eq!(t.f64_or("prune.adam.lr", 0.0), 0.01);
        match t.get("prune.adam.steps").unwrap() {
            Value::Arr(a) => assert_eq!(a.len(), 3),
            other => panic!("prune.adam.steps should parse as an array, got {other:?}"),
        }
    }

    #[test]
    fn hash_in_string_kept() {
        let t = Toml::parse(r#"k = "a#b" # comment"#).unwrap();
        assert_eq!(t.str_or("k", ""), "a#b");
    }

    #[test]
    fn errors() {
        assert!(Toml::parse("[x").is_err());
        assert!(Toml::parse("novalue").is_err());
        assert!(Toml::parse("k = @").is_err());
    }
}
