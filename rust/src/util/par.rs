//! Scoped-thread fan-out: [`par_map`] for the coordinator's per-minibatch
//! loops and the sparse kernels' row blocks, [`scoped_workers`] for
//! long-lived indexed worker pools (the online serving engine).
//!
//! No external threadpool crate (offline build): a work-stealing index
//! over `std::thread::scope`. Results keep input order; the first error
//! wins and the rest of the batch is abandoned cooperatively.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Duration;

use anyhow::{anyhow, Result};

/// Acquire a mutex, recovering the guard from a poisoned lock. Poisoning
/// only means some other thread panicked while holding the guard; our
/// shared states stay structurally valid across panics (queues, counters),
/// so continuing is strictly better than cascading the panic through the
/// serving hot path.
pub fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// [`Condvar::wait`] with the same poison recovery as [`locked`].
pub fn wait_on<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|e| e.into_inner())
}

/// [`Condvar::wait_timeout`] with the same poison recovery as [`locked`].
/// The timed-out flag is dropped: every caller re-checks its predicate
/// under the returned guard anyway.
pub fn wait_timeout_on<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    timeout: Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(g, timeout) {
        Ok((g, _)) => g,
        Err(e) => e.into_inner().0,
    }
}

/// Number of worker threads for `n` items: capped by available
/// parallelism and by the item count; at least 1.
pub fn workers_for(n: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    hw.min(n).max(1)
}

/// Apply `f` to every item on a scoped thread pool, preserving order.
/// Falls back to a plain sequential loop when only one worker is useful
/// (zero thread overhead for tiny batches).
pub fn par_map<T, R, F>(items: &[T], f: F) -> Result<Vec<R>>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> Result<R> + Sync,
{
    let n = items.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = workers_for(n);
    if workers == 1 {
        return items.iter().map(&f).collect();
    }

    let next = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    let slots: Vec<Mutex<Option<Result<R>>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n || failed.load(Ordering::Relaxed) {
                    break;
                }
                let r = f(&items[i]);
                if r.is_err() {
                    failed.store(true, Ordering::Relaxed);
                }
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });

    let mut out = Vec::with_capacity(n);
    let mut first_err = None;
    let mut abandoned = false;
    for slot in slots {
        match slot.into_inner().unwrap() {
            Some(Ok(v)) => out.push(v),
            // keep the earliest recorded root-cause error
            Some(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
            // claimed after the failure and abandoned unprocessed
            None => abandoned = true,
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    if abandoned {
        return Err(anyhow!("parallel batch abandoned after an earlier failure"));
    }
    Ok(out)
}

/// Spawn a named OS thread (visible in debuggers and panic messages),
/// surfacing spawn failure as a `Result` instead of panicking. Unlike
/// [`scoped_workers`] the thread is *detached from the caller's stack
/// frame* — the closure must be `'static` (the TCP front end moves an
/// `Arc` of its shared state in) — and the caller keeps the
/// [`std::thread::JoinHandle`].
pub fn spawn_named<T, F>(name: &str, f: F) -> Result<std::thread::JoinHandle<T>>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    std::thread::Builder::new()
        .name(name.to_string())
        .spawn(f)
        .map_err(|e| anyhow!("spawning thread '{name}': {e}"))
}

/// Run `n` long-lived indexed workers (`f(0)..f(n-1)`) on scoped threads
/// and collect their results in index order. Unlike [`par_map`] — which
/// steals small uniform items — each call here *is* one worker for its
/// whole lifetime: the online serving engine passes a closure that runs a
/// producer or a continuous-batching worker loop until the request queue
/// drains. A panicking worker is *reported, not cascaded*: every other
/// worker is joined first, then one panic naming the dead workers
/// propagates — so a single death can never abort the process mid-join
/// while siblings still run (use [`try_scoped_workers`] to handle worker
/// deaths without panicking at all).
pub fn scoped_workers<R, F>(n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let results = try_scoped_workers(n, f);
    let mut dead: Vec<String> = Vec::new();
    let mut out = Vec::with_capacity(results.len());
    for (i, r) in results.into_iter().enumerate() {
        match r {
            Ok(v) => out.push(v),
            Err(payload) => dead.push(format!("worker {i}: {}", panic_message(&payload))),
        }
    }
    if !dead.is_empty() {
        panic!("{} scoped worker(s) panicked — {}", dead.len(), dead.join("; "));
    }
    out
}

/// [`scoped_workers`] with per-worker panic isolation: every worker is
/// joined and its result returned as `Ok(value)` or `Err(payload)` in
/// index order. One worker's death never takes down its siblings or the
/// caller — the supervision layer in `serve::online` builds on this.
pub fn try_scoped_workers<R, F>(n: usize, f: F) -> Vec<std::thread::Result<R>>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    if n == 1 {
        // same isolation as the threaded path: catch instead of unwinding
        // through the caller (the closure is reconstructible state — the
        // payload is reported, never silently reused)
        return vec![std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(0)))];
    }
    std::thread::scope(|scope| {
        let f = &f;
        let handles: Vec<_> = (0..n).map(|i| scope.spawn(move || f(i))).collect();
        // join() already returns Err(payload) for a panicked thread —
        // collect them all so every worker is reaped before anyone reacts
        handles.into_iter().map(|h| h.join()).collect()
    })
}

/// Best-effort human-readable text of a panic payload (`&str` and
/// `String` payloads cover `panic!`/`assert!`; anything else is opaque).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "<non-string panic payload>"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<usize> = (0..37).collect();
        let out = par_map(&items, |x| Ok(x * 2)).unwrap();
        assert_eq!(out, (0..37).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn propagates_errors() {
        let items: Vec<usize> = (0..16).collect();
        let r = par_map(&items, |x| {
            if *x == 7 {
                anyhow::bail!("boom at {x}")
            } else {
                Ok(*x)
            }
        });
        assert!(r.is_err());
    }

    #[test]
    fn empty_ok() {
        let items: Vec<u8> = vec![];
        assert!(par_map(&items, |x| Ok(*x)).unwrap().is_empty());
    }

    #[test]
    fn scoped_workers_index_order() {
        assert!(scoped_workers(0, |i| i).is_empty());
        assert_eq!(scoped_workers(1, |i| i * 3), vec![0]);
        assert_eq!(scoped_workers(5, |i| i * 3), vec![0, 3, 6, 9, 12]);
    }

    #[test]
    fn spawn_named_runs_and_joins() {
        let h = spawn_named("besa-test-thread", || {
            assert_eq!(std::thread::current().name(), Some("besa-test-thread"));
            41 + 1
        })
        .unwrap();
        assert_eq!(h.join().unwrap(), 42);
    }

    #[test]
    fn scoped_workers_run_concurrently() {
        // a barrier only passes if all workers are alive at once
        let barrier = std::sync::Barrier::new(4);
        let out = scoped_workers(4, |i| {
            barrier.wait();
            i
        });
        assert_eq!(out, vec![0, 1, 2, 3]);
    }

    #[test]
    fn try_scoped_workers_isolates_panics() {
        let results = try_scoped_workers(4, |i| {
            if i == 2 {
                panic!("boom at {i}");
            }
            i * 10
        });
        assert_eq!(results.len(), 4);
        for (i, r) in results.iter().enumerate() {
            match r {
                Ok(v) => {
                    assert_ne!(i, 2);
                    assert_eq!(*v, i * 10);
                }
                Err(p) => {
                    assert_eq!(i, 2);
                    assert_eq!(panic_message(p.as_ref()), "boom at 2");
                }
            }
        }
        // n == 1 path catches too
        let one = try_scoped_workers(1, |_| -> usize { panic!("solo") });
        assert!(one[0].is_err());
    }

    #[test]
    fn scoped_workers_reports_not_cascades() {
        // the surviving workers still complete (the barrier proves all 3
        // were joined, not aborted by worker 1's death), and the final
        // panic names the dead worker
        let done = std::sync::atomic::AtomicUsize::new(0);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            scoped_workers(3, |i| {
                if i == 1 {
                    panic!("die");
                }
                done.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                i
            })
        }));
        let payload = caught.expect_err("a worker death must still be reported");
        let msg = panic_message(payload.as_ref());
        assert!(msg.contains("worker 1"), "panic names the dead worker: {msg}");
        assert_eq!(done.load(std::sync::atomic::Ordering::SeqCst), 2);
    }
}
