//! Scoped-thread fan-out for the coordinator's per-minibatch loops.
//!
//! No external threadpool crate (offline build): a work-stealing index
//! over `std::thread::scope`. Results keep input order; the first error
//! wins and the rest of the batch is abandoned cooperatively.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, Result};

/// Number of worker threads for `n` items: capped by available
/// parallelism and by the item count; at least 1.
pub fn workers_for(n: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    hw.min(n).max(1)
}

/// Apply `f` to every item on a scoped thread pool, preserving order.
/// Falls back to a plain sequential loop when only one worker is useful
/// (zero thread overhead for tiny batches).
pub fn par_map<T, R, F>(items: &[T], f: F) -> Result<Vec<R>>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> Result<R> + Sync,
{
    let n = items.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = workers_for(n);
    if workers == 1 {
        return items.iter().map(&f).collect();
    }

    let next = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    let slots: Vec<Mutex<Option<Result<R>>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n || failed.load(Ordering::Relaxed) {
                    break;
                }
                let r = f(&items[i]);
                if r.is_err() {
                    failed.store(true, Ordering::Relaxed);
                }
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });

    let mut out = Vec::with_capacity(n);
    let mut first_err = None;
    let mut abandoned = false;
    for slot in slots {
        match slot.into_inner().unwrap() {
            Some(Ok(v)) => out.push(v),
            // keep the earliest recorded root-cause error
            Some(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
            // claimed after the failure and abandoned unprocessed
            None => abandoned = true,
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    if abandoned {
        return Err(anyhow!("parallel batch abandoned after an earlier failure"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<usize> = (0..37).collect();
        let out = par_map(&items, |x| Ok(x * 2)).unwrap();
        assert_eq!(out, (0..37).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn propagates_errors() {
        let items: Vec<usize> = (0..16).collect();
        let r = par_map(&items, |x| {
            if *x == 7 {
                anyhow::bail!("boom at {x}")
            } else {
                Ok(*x)
            }
        });
        assert!(r.is_err());
    }

    #[test]
    fn empty_ok() {
        let items: Vec<u8> = vec![];
        assert!(par_map(&items, |x| Ok(*x)).unwrap().is_empty());
    }
}
