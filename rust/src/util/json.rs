//! Minimal JSON: recursive-descent parser + writer.
//!
//! Used for the AOT artifact manifests (read) and experiment result files
//! (write). Supports the full JSON grammar except `\u` surrogate pairs
//! beyond the BMP (manifests are ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn at(&self, path: &[&str]) -> &Json {
        static NULL: Json = Json::Null;
        let mut cur = self;
        for k in path {
            cur = cur.get(k).unwrap_or(&NULL);
        }
        cur
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders for experiment result emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(vals: I) -> Json {
    Json::Arr(vals.into_iter().collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => bail!("expected , or ] got {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => bail!("expected , or }} got {:?}", other.map(|c| c as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.at(&["b", "d"]), &Json::Bool(true));
        assert_eq!(v.at(&["a"]).as_arr().unwrap()[2].as_f64(), Some(-300.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let v = obj(vec![("k", arr(vec![num(1.0), s("two")]))]);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Ab""#).unwrap();
        assert_eq!(v.as_str(), Some("Ab"));
    }
}
