//! Property-based testing harness (proptest replacement, offline build).
//!
//! `check(name, cases, gen, prop)` draws `cases` random inputs from `gen`,
//! asserts `prop` on each, and on failure performs greedy shrinking via the
//! generator's `shrink` candidates before panicking with the minimal
//! counterexample. Seeds derive from `BESA_PROPTEST_SEED` (default 0xBE5A)
//! so failures reproduce deterministically.

use std::fmt::Debug;

use super::rng::Rng;

pub trait Strategy {
    type Value: Clone + Debug;
    fn sample(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller values; default: no shrinking.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

pub fn seed_from_env() -> u64 {
    std::env::var("BESA_PROPTEST_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xBE5A)
}

pub fn check<S: Strategy>(
    name: &str,
    cases: usize,
    strat: &S,
    prop: impl Fn(&S::Value) -> Result<(), String>,
) {
    let mut rng = Rng::seed(seed_from_env() ^ fxhash(name));
    for case in 0..cases {
        let v = strat.sample(&mut rng);
        if let Err(msg) = prop(&v) {
            // greedy shrink
            let mut best = (v.clone(), msg.clone());
            let mut progress = true;
            let mut rounds = 0;
            while progress && rounds < 200 {
                progress = false;
                rounds += 1;
                for cand in strat.shrink(&best.0) {
                    if let Err(m) = prop(&cand) {
                        best = (cand, m);
                        progress = true;
                        break;
                    }
                }
            }
            panic!(
                "property '{name}' failed at case {case}\n  counterexample (shrunk): {:?}\n  reason: {}",
                best.0, best.1
            );
        }
    }
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Common strategies
// ---------------------------------------------------------------------------

pub struct UsizeIn(pub std::ops::RangeInclusive<usize>);

impl Strategy for UsizeIn {
    type Value = usize;
    fn sample(&self, rng: &mut Rng) -> usize {
        let (lo, hi) = (*self.0.start(), *self.0.end());
        lo + rng.below(hi - lo + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let lo = *self.0.start();
        if *v > lo {
            vec![lo, lo + (*v - lo) / 2, v - 1]
        } else {
            vec![]
        }
    }
}

pub struct F32Vec {
    pub len: std::ops::RangeInclusive<usize>,
    pub lo: f32,
    pub hi: f32,
}

impl Strategy for F32Vec {
    type Value = Vec<f32>;
    fn sample(&self, rng: &mut Rng) -> Vec<f32> {
        let n = UsizeIn(self.len.clone()).sample(rng);
        (0..n).map(|_| rng.range_f64(self.lo as f64, self.hi as f64) as f32).collect()
    }
    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > *self.len.start() {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        if v.iter().any(|x| *x != 0.0) {
            out.push(v.iter().map(|_| 0.0).collect());
        }
        out
    }
}

/// Pair two independent strategies.
pub struct Zip<A, B>(pub A, pub B);

impl<A: Strategy, B: Strategy> Strategy for Zip<A, B> {
    type Value = (A::Value, B::Value);
    fn sample(&self, rng: &mut Rng) -> Self::Value {
        (self.0.sample(rng), self.1.sample(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(&v.0).into_iter().map(|a| (a, v.1.clone())).collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially() {
        check("usize in range", 100, &UsizeIn(3..=9), |v| {
            if (3..=9).contains(v) {
                Ok(())
            } else {
                Err(format!("{v} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "counterexample")]
    fn shrinks_to_minimal() {
        // property "all values < 5" fails; shrinker should find something small
        check("fails above 5", 200, &UsizeIn(0..=100), |v| {
            if *v < 5 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn vec_strategy_in_bounds() {
        let s = F32Vec { len: 1..=8, lo: -2.0, hi: 2.0 };
        check("vec bounds", 50, &s, |v| {
            if v.iter().all(|x| (-2.0..=2.0).contains(x)) && (1..=8).contains(&v.len()) {
                Ok(())
            } else {
                Err(format!("{v:?}"))
            }
        });
    }
}
