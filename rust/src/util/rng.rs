//! Deterministic PRNG substrate (no external `rand`): splitmix64 seeding +
//! xoshiro256** generation, with normal/uniform/choice helpers used by
//! weight init, corpus generation and the property-testing harness.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (for parallel corpus shards etc.).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::seed(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut v: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed(42);
        let mut b = Rng::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::seed(1);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "{m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / n as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64;
        assert!(m.abs() < 0.03, "{m}");
        assert!((v - 1.0).abs() < 0.05, "{v}");
    }

    #[test]
    fn permutation_valid() {
        let mut r = Rng::seed(3);
        let mut p = r.permutation(100);
        p.sort();
        assert_eq!(p, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn weighted_respects_zero() {
        let mut r = Rng::seed(4);
        for _ in 0..100 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn forks_diverge() {
        let mut r = Rng::seed(5);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
