//! Micro-benchmark harness (criterion replacement, offline build).
//!
//! Each `cargo bench` target builds a [`Bench`] suite: warmup, timed
//! iterations until a minimum wall budget, robust stats (mean/p50/p95),
//! throughput annotation, and text rows that double as the paper-table
//! regeneration output.

use std::time::Instant;

use super::{mean, percentile, stddev};

pub struct Bench {
    name: String,
    warmup_iters: usize,
    min_secs: f64,
    max_iters: usize,
    rows: Vec<Row>,
}

#[derive(Debug, Clone)]
pub struct Row {
    pub id: String,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub std_ns: f64,
    pub iters: usize,
    pub throughput: Option<(f64, &'static str)>,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            warmup_iters: 3,
            min_secs: std::env::var("BESA_BENCH_SECS")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(1.0),
            max_iters: 1000,
            rows: Vec::new(),
        }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup_iters = n;
        self
    }

    pub fn budget_secs(mut self, s: f64) -> Self {
        self.min_secs = s;
        self
    }

    /// Time `f`, which returns a value to keep it from being optimized out.
    pub fn run<T, F: FnMut() -> T>(&mut self, id: &str, mut f: F) -> &Row {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let budget = Instant::now();
        while budget.elapsed().as_secs_f64() < self.min_secs && samples.len() < self.max_iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let row = Row {
            id: id.to_string(),
            mean_ns: mean(&samples),
            p50_ns: percentile(&samples, 50.0),
            p95_ns: percentile(&samples, 95.0),
            std_ns: stddev(&samples),
            iters: samples.len(),
            throughput: None,
        };
        self.rows.push(row);
        self.rows.last().unwrap()
    }

    /// Same but annotate throughput as `items`/sec.
    pub fn run_throughput<T, F: FnMut() -> T>(
        &mut self,
        id: &str,
        items: f64,
        unit: &'static str,
        f: F,
    ) -> &Row {
        self.run(id, f);
        let row = self.rows.last_mut().unwrap();
        row.throughput = Some((items / (row.mean_ns / 1e9), unit));
        row
    }

    pub fn rows(&self) -> &[Row] {
        &self.rows
    }

    pub fn report(&self) {
        println!("\n== bench: {} ==", self.name);
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>8}  throughput",
            "case", "mean", "p50", "p95", "iters"
        );
        for r in &self.rows {
            let tp = match r.throughput {
                Some((v, u)) => format!("{} {}", human_rate(v), u),
                None => String::new(),
            };
            println!(
                "{:<44} {:>12} {:>12} {:>12} {:>8}  {}",
                r.id,
                human_ns(r.mean_ns),
                human_ns(r.p50_ns),
                human_ns(r.p95_ns),
                r.iters,
                tp
            );
        }
    }
}

pub fn human_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub fn human_rate(v: f64) -> String {
    if v >= 1e9 {
        format!("{:.2}G", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.2}k", v / 1e3)
    } else {
        format!("{v:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_rows() {
        let mut b = Bench::new("t").warmup(1).budget_secs(0.01);
        b.run("noop", || 1 + 1);
        b.run_throughput("tp", 100.0, "items", || std::hint::black_box(7u64).pow(3));
        assert_eq!(b.rows().len(), 2);
        assert!(b.rows()[0].iters > 0);
        assert!(b.rows()[1].throughput.unwrap().0 > 0.0);
    }

    #[test]
    fn humanize() {
        assert_eq!(human_ns(500.0), "500 ns");
        assert!(human_ns(2.5e6).contains("ms"));
        assert!(human_rate(3.2e6).contains('M'));
    }
}
