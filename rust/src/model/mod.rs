//! Model substrate: configuration mirror of `python/compile/configs.py`,
//! parameter store (named tensors in the canonical order shared with the
//! AOT graphs), initialization, and checkpointing.

pub mod config;
pub mod params;

pub use config::ModelConfig;
pub use params::{ParamStore, LAYER_NAMES};
