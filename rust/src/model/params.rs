//! Parameter store: named tensors in the canonical manifest order, with
//! LLaMA-style initialization and `.bst` checkpointing.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::{io, Tensor};
use crate::util::rng::Rng;

use super::config::ModelConfig;

pub use super::config::LAYER_NAMES;

#[derive(Debug, Clone)]
pub struct ParamStore {
    pub config_name: String,
    tensors: BTreeMap<String, Tensor>,
    order: Vec<String>,
}

impl ParamStore {
    /// Random init: truncated-normal-ish scaled by 1/sqrt(fan_in) for the
    /// projections, N(0, 0.02) embeddings, ones for norms.
    pub fn init(cfg: &ModelConfig, seed: u64) -> ParamStore {
        let mut rng = Rng::seed(seed);
        let mut tensors = BTreeMap::new();
        for name in &cfg.param_order {
            let shape = cfg.param_shape(name);
            let t = if shape.len() == 1 {
                Tensor::ones(&shape)
            } else if name == "embed" {
                let n = crate::tensor::numel(&shape);
                Tensor::from_f32(&shape, (0..n).map(|_| rng.normal_f32() * 0.02).collect())
            } else {
                let fan_in = shape[1] as f32;
                let std = 1.0 / fan_in.sqrt();
                let n = crate::tensor::numel(&shape);
                Tensor::from_f32(&shape, (0..n).map(|_| rng.normal_f32() * std).collect())
            };
            tensors.insert(name.clone(), t);
        }
        ParamStore { config_name: cfg.name.clone(), tensors, order: cfg.param_order.clone() }
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).with_context(|| format!("missing param '{name}'"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.tensors.get_mut(name).with_context(|| format!("missing param '{name}'"))
    }

    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        match self.tensors.get(name) {
            Some(old) if old.shape != t.shape => {
                bail!("set '{name}': shape {:?} != existing {:?}", t.shape, old.shape)
            }
            _ => {}
        }
        self.tensors.insert(name.to_string(), t);
        Ok(())
    }

    pub fn order(&self) -> &[String] {
        &self.order
    }

    /// Tensors in canonical (manifest) order, for positional artifact input.
    pub fn ordered(&self) -> Vec<&Tensor> {
        self.order.iter().map(|n| &self.tensors[n]).collect()
    }

    /// The seven prunable weights of block `l`, in LAYER_NAMES order.
    pub fn block_weights(&self, l: usize) -> Vec<&Tensor> {
        LAYER_NAMES.iter().map(|w| &self.tensors[&format!("blocks.{l}.{w}")]).collect()
    }

    pub fn block_norms(&self, l: usize) -> [&Tensor; 2] {
        [
            &self.tensors[&format!("blocks.{l}.norm1")],
            &self.tensors[&format!("blocks.{l}.norm2")],
        ]
    }

    pub fn layer_name(l: usize, w: &str) -> String {
        format!("blocks.{l}.{w}")
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        io::save(path, &self.tensors)
    }

    pub fn load(cfg: &ModelConfig, path: &Path) -> Result<ParamStore> {
        let tensors = io::load(path)?;
        for name in &cfg.param_order {
            let t = tensors
                .get(name)
                .with_context(|| format!("checkpoint {} missing '{name}'", path.display()))?;
            let want = cfg.param_shape(name);
            if t.shape != want {
                bail!("checkpoint '{name}': shape {:?} != config {:?}", t.shape, want);
            }
        }
        Ok(ParamStore {
            config_name: cfg.name.clone(),
            tensors,
            order: cfg.param_order.clone(),
        })
    }

    /// Apply a set of 0/1 masks multiplicatively to block weights.
    pub fn apply_masks(&mut self, l: usize, masks: &BTreeMap<String, Tensor>) -> Result<()> {
        for w in LAYER_NAMES {
            let name = Self::layer_name(l, w);
            let mask = masks.get(w).with_context(|| format!("missing mask {w}"))?;
            let t = self.get_mut(&name)?;
            if mask.shape != t.shape {
                bail!("mask {w}: shape {:?} != weight {:?}", mask.shape, t.shape);
            }
            for (v, m) in t.f32s_mut().iter_mut().zip(mask.f32s()) {
                *v *= m;
            }
        }
        Ok(())
    }

    /// Global sparsity over the seven prunable weights of all blocks.
    pub fn prunable_sparsity(&self, n_blocks: usize) -> f64 {
        let mut zeros = 0usize;
        let mut total = 0usize;
        for l in 0..n_blocks {
            for w in LAYER_NAMES {
                let t = &self.tensors[&Self::layer_name(l, w)];
                zeros += t.f32s().iter().filter(|x| **x == 0.0).count();
                total += t.numel();
            }
        }
        zeros as f64 / total.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::tests::test_config;

    #[test]
    fn init_shapes_and_determinism() {
        let cfg = test_config();
        let a = ParamStore::init(&cfg, 7);
        let b = ParamStore::init(&cfg, 7);
        let c = ParamStore::init(&cfg, 8);
        assert_eq!(a.get("embed").unwrap().shape, vec![256, 32]);
        assert_eq!(a.get("blocks.0.wq").unwrap().f32s(), b.get("blocks.0.wq").unwrap().f32s());
        assert_ne!(a.get("blocks.0.wq").unwrap().f32s(), c.get("blocks.0.wq").unwrap().f32s());
        assert_eq!(a.ordered().len(), cfg.param_order.len());
    }

    #[test]
    fn checkpoint_roundtrip() {
        let cfg = test_config();
        let p = ParamStore::init(&cfg, 1);
        let dir = std::env::temp_dir().join(format!("params_test_{}", std::process::id()));
        let path = dir.join("m.bst");
        p.save(&path).unwrap();
        let q = ParamStore::load(&cfg, &path).unwrap();
        assert_eq!(p.get("blocks.1.wd").unwrap(), q.get("blocks.1.wd").unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn masks_apply() {
        let cfg = test_config();
        let mut p = ParamStore::init(&cfg, 2);
        let mut masks = BTreeMap::new();
        for w in LAYER_NAMES {
            let shape = cfg.layer_shape(w);
            let mut m = Tensor::ones(&[shape[0], shape[1]]);
            let half = m.numel() / 2;
            m.f32s_mut()[..half].iter_mut().for_each(|v| *v = 0.0);
            masks.insert(w.to_string(), m);
        }
        p.apply_masks(0, &masks).unwrap();
        let s = p.prunable_sparsity(1);
        assert!((s - 0.5).abs() < 0.02, "{s}");
    }

    #[test]
    fn set_rejects_shape_change() {
        let cfg = test_config();
        let mut p = ParamStore::init(&cfg, 3);
        assert!(p.set("norm_f", Tensor::zeros(&[7])).is_err());
        assert!(p.set("norm_f", Tensor::zeros(&[32])).is_ok());
    }
}
