//! Model configuration. Two sources of truth that can never disagree:
//! the artifact manifest (PJRT backend — shapes are whatever python lowered)
//! and the built-in config table below (native backend — mirrors
//! `python/compile/configs.py` exactly, so the same `test|sm|md|lg` names
//! work with no artifacts on disk).

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_blocks: usize,
    pub d_ffn: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub n_rates: usize,
    /// extra candidate-rate counts lowered as `besa_step_row_d<N>` variants
    /// (Table 5 sparsity-step ablation)
    pub alt_rates: Vec<usize>,
    pub rope_base: f64,
    pub norm_eps: f64,
    pub param_order: Vec<String>,
}

/// The seven prunable projections of one block, in pipeline order
/// (must match python/compile/configs.py LAYER_NAMES).
pub const LAYER_NAMES: [&str; 7] = ["wq", "wk", "wv", "wo", "wg", "wu", "wd"];

fn canonical_param_order(n_blocks: usize) -> Vec<String> {
    let mut order = vec!["embed".to_string()];
    for l in 0..n_blocks {
        for w in LAYER_NAMES {
            order.push(format!("blocks.{l}.{w}"));
        }
        order.push(format!("blocks.{l}.norm1"));
        order.push(format!("blocks.{l}.norm2"));
    }
    order.push("norm_f".to_string());
    order
}

impl ModelConfig {
    pub fn from_json(v: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: v.at(&["name"]).as_str().context("config.name")?.to_string(),
            vocab: v.at(&["vocab"]).as_usize().context("vocab")?,
            d_model: v.at(&["d_model"]).as_usize().context("d_model")?,
            n_heads: v.at(&["n_heads"]).as_usize().context("n_heads")?,
            n_blocks: v.at(&["n_blocks"]).as_usize().context("n_blocks")?,
            d_ffn: v.at(&["d_ffn"]).as_usize().context("d_ffn")?,
            seq_len: v.at(&["seq_len"]).as_usize().context("seq_len")?,
            batch: v.at(&["batch"]).as_usize().context("batch")?,
            n_rates: v.at(&["n_rates"]).as_usize().context("n_rates")?,
            // not recorded in older manifests; irrelevant to PJRT execution
            // (shapes are baked into the HLO) but needed by the native math
            alt_rates: Vec::new(),
            rope_base: v.at(&["rope_base"]).as_f64().unwrap_or(10000.0),
            norm_eps: v.at(&["norm_eps"]).as_f64().context("norm_eps")?,
            param_order: v
                .at(&["param_order"])
                .as_arr()
                .context("param_order")?
                .iter()
                .map(|s| s.as_str().unwrap().to_string())
                .collect(),
        })
    }

    /// The built-in config table (mirrors python/compile/configs.py
    /// CONFIGS). This is what lets the native backend run with zero
    /// artifacts on disk.
    pub fn builtin(name: &str) -> Result<ModelConfig> {
        let (vocab, d_model, n_heads, n_blocks, d_ffn, seq_len, batch, n_rates, alt): (
            usize,
            usize,
            usize,
            usize,
            usize,
            usize,
            usize,
            usize,
            &[usize],
        ) = match name {
            "test" => (256, 32, 2, 2, 88, 32, 4, 16, &[]),
            "sm" => (256, 64, 4, 4, 172, 64, 8, 32, &[8, 64]),
            "md" => (256, 128, 4, 8, 344, 128, 8, 100, &[]),
            "lg" => (256, 192, 8, 8, 516, 128, 8, 100, &[]),
            other => bail!("unknown built-in config '{other}' (have: test|sm|md|lg)"),
        };
        Ok(ModelConfig {
            name: name.to_string(),
            vocab,
            d_model,
            n_heads,
            n_blocks,
            d_ffn,
            seq_len,
            batch,
            n_rates,
            alt_rates: alt.to_vec(),
            rope_base: 10000.0,
            norm_eps: 1e-5,
            param_order: canonical_param_order(n_blocks),
        })
    }

    pub fn d_head(&self) -> usize {
        debug_assert_eq!(self.d_model % self.n_heads, 0);
        self.d_model / self.n_heads
    }

    /// Shape of one of the seven prunable weights, `[out, in]`.
    pub fn layer_shape(&self, layer: &str) -> [usize; 2] {
        let (d, f) = (self.d_model, self.d_ffn);
        match layer {
            "wq" | "wk" | "wv" | "wo" => [d, d],
            "wg" | "wu" => [f, d],
            "wd" => [d, f],
            other => panic!("unknown layer {other}"),
        }
    }

    /// Shape of any named parameter.
    pub fn param_shape(&self, name: &str) -> Vec<usize> {
        if name == "embed" {
            return vec![self.vocab, self.d_model];
        }
        if name == "norm_f" || name.ends_with("norm1") || name.ends_with("norm2") {
            return vec![self.d_model];
        }
        let layer = name.rsplit('.').next().unwrap();
        self.layer_shape(layer).to_vec()
    }

    pub fn block_param_count(&self) -> usize {
        LAYER_NAMES
            .iter()
            .map(|l| {
                let s = self.layer_shape(l);
                s[0] * s[1]
            })
            .sum()
    }

    pub fn total_param_count(&self) -> usize {
        self.param_order.iter().map(|n| crate::tensor::numel(&self.param_shape(n))).sum()
    }

    pub fn tokens_per_batch(&self) -> usize {
        self.batch * self.seq_len
    }
}

#[cfg(test)]
pub mod tests {
    use super::*;

    pub fn test_config() -> ModelConfig {
        ModelConfig::builtin("test").unwrap()
    }

    #[test]
    fn shapes() {
        let c = test_config();
        assert_eq!(c.layer_shape("wq"), [32, 32]);
        assert_eq!(c.layer_shape("wg"), [88, 32]);
        assert_eq!(c.layer_shape("wd"), [32, 88]);
        assert_eq!(c.param_shape("embed"), vec![256, 32]);
        assert_eq!(c.param_shape("blocks.1.norm2"), vec![32]);
        assert_eq!(c.param_shape("blocks.0.wu"), vec![88, 32]);
        assert_eq!(c.block_param_count(), 4 * 32 * 32 + 3 * 88 * 32);
        assert_eq!(c.d_head(), 16);
    }

    #[test]
    fn param_count_consistent() {
        let c = test_config();
        let total = c.total_param_count();
        assert_eq!(total, 256 * 32 + 2 * (c.block_param_count() + 2 * 32) + 32);
    }

    #[test]
    fn builtin_configs_parse() {
        for name in ["test", "sm", "md", "lg"] {
            let c = ModelConfig::builtin(name).unwrap();
            assert_eq!(c.name, name);
            assert_eq!(c.param_order.len(), 1 + c.n_blocks * 9 + 1);
            assert_eq!(c.d_model % c.n_heads, 0);
        }
        assert!(ModelConfig::builtin("nope").is_err());
        assert_eq!(ModelConfig::builtin("sm").unwrap().alt_rates, vec![8, 64]);
    }
}
