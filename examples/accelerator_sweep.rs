//! Accelerator-deployment study (extends paper §4.5): how BESA's learned
//! non-uniform sparsity translates to ViTCoD hardware speedup, compared to
//! uniform pruning, across accelerator configurations (PE split, density
//! threshold). This is the exploration a deployment engineer runs before
//! committing to a hardware config.
//!
//! ```bash
//! cargo run --release --example accelerator_sweep
//! ```

use besa::coordinator::Pipeline;
use besa::data::batcher::CalibrationSet;
use besa::model::{ParamStore, LAYER_NAMES};
use besa::prune::besa::{BesaConfig, BesaPruner};
use besa::prune::wanda::WandaPruner;
use besa::runtime::Engine;
use besa::sim::{simulate_block, SimConfig};

fn main() -> anyhow::Result<()> {
    besa::util::logging::init_from_env();
    let config = std::env::var("BESA_SWEEP_CONFIG").unwrap_or_else(|_| "test".to_string());
    let engine = Engine::new(std::path::Path::new("artifacts"), &config)?;
    let cfg = engine.config().clone();

    // a pruned model per method (fresh init is fine: the sim only reads masks)
    let ckpt = std::path::PathBuf::from(format!("runs/{config}-dense.bst"));
    let dense = if ckpt.exists() {
        ParamStore::load(&cfg, &ckpt)?
    } else {
        ParamStore::init(&cfg, 9)
    };
    let calib = CalibrationSet::sample(&cfg, cfg.batch, 5);

    let mut besa_m = dense.clone();
    Pipeline::new(&engine, calib.batches.clone())
        .run(&mut besa_m, &mut BesaPruner::new(BesaConfig::default()))?;
    let mut wanda_m = dense.clone();
    Pipeline::new(&engine, calib.batches).run(&mut wanda_m, &mut WandaPruner { sparsity: 0.5 })?;

    println!("\n== ViTCoD config sweep: end-to-end block speedup ==");
    println!(
        "{:<28} {:>12} {:>12} {:>10}",
        "accelerator config", "wanda", "besa", "besa/wanda"
    );
    for (name, denser, sparser, thresh) in [
        ("64+64 PEs, thresh 0.5", 64usize, 64usize, 0.5f64),
        ("96+32 PEs, thresh 0.5", 96, 32, 0.5),
        ("32+96 PEs, thresh 0.5", 32, 96, 0.5),
        ("64+64 PEs, thresh 0.25", 64, 64, 0.25),
        ("64+64 PEs, thresh 0.75", 64, 64, 0.75),
    ] {
        let sim = SimConfig {
            denser_pes: denser,
            sparser_pes: sparser,
            density_threshold: thresh,
            tokens: cfg.seq_len,
            ..Default::default()
        };
        let total = |p: &ParamStore| -> anyhow::Result<(u64, u64)> {
            let sims = simulate_block(p, &cfg, &sim)?;
            Ok((
                sims.iter().map(|s| s.sparse_cycles).sum::<u64>(),
                sims.iter().map(|s| s.dense_cycles).sum::<u64>(),
            ))
        };
        let (wc, dc) = total(&wanda_m)?;
        let (bc, _) = total(&besa_m)?;
        println!(
            "{name:<28} {:>11.2}x {:>11.2}x {:>10.3}",
            dc as f64 / wc as f64,
            dc as f64 / bc as f64,
            wc as f64 / bc as f64
        );
    }

    println!("\n== per-layer BESA sparsity allocation (block 0) ==");
    for w in LAYER_NAMES {
        let t = besa_m.get(&ParamStore::layer_name(0, w))?;
        println!("  {w:<6} sparsity {:.2}%", t.zero_fraction() * 100.0);
    }
    Ok(())
}
