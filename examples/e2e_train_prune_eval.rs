//! End-to-end driver (the repo's required full-system validation):
//! pretrains the `md` model (~1.8M params, LLaMA architecture) on the
//! synthetic three-domain corpus for a few hundred steps, logs the loss
//! curve, runs the complete BESA pipeline against the SparseGPT and Wanda
//! baselines, and reports the paper's headline metric — perplexity at 50%
//! unstructured sparsity on all three evaluation domains — plus zero-shot
//! probe accuracy. Results land in results/e2e.json and EXPERIMENTS.md
//! quotes this run.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train_prune_eval
//! # smaller/faster: BESA_E2E_CONFIG=sm BESA_E2E_STEPS=200 cargo run ...
//! ```

use besa::coordinator::{trainer, Pipeline};
use besa::data::batcher::CalibrationSet;
use besa::model::ParamStore;
use besa::prune::besa::{BesaConfig, BesaPruner};
use besa::prune::sparsegpt::SparseGptPruner;
use besa::prune::wanda::WandaPruner;
use besa::runtime::Engine;
use besa::util::json::{self, Json};
use besa::util::Stopwatch;

fn env_or(name: &str, default: &str) -> String {
    std::env::var(name).unwrap_or_else(|_| default.to_string())
}

fn main() -> anyhow::Result<()> {
    besa::util::logging::init_from_env();
    let config = env_or("BESA_E2E_CONFIG", "md");
    let steps: usize = env_or("BESA_E2E_STEPS", "300").parse()?;
    let engine = Engine::new(std::path::Path::new("artifacts"), &config)?;
    let cfg = engine.config().clone();
    println!(
        "== e2e: config {config} ({} params, {} blocks, d={} ffn={}) ==",
        cfg.total_param_count(),
        cfg.n_blocks,
        cfg.d_model,
        cfg.d_ffn
    );

    // ---- 1. pretrain (or reuse the checkpoint from `besa pretrain`) ------
    let ckpt = std::path::PathBuf::from(format!("runs/{config}-dense.bst"));
    let mut dense;
    let mut loss_curve = Vec::new();
    if ckpt.exists() {
        println!("using existing checkpoint {}", ckpt.display());
        dense = ParamStore::load(&cfg, &ckpt)?;
    } else {
        dense = ParamStore::init(&cfg, 1234);
        let sw = Stopwatch::start();
        let stats = trainer::pretrain(
            &engine,
            &mut dense,
            &trainer::TrainConfig { steps, lr: 3e-3, seed: 1234, log_every: 20 },
        )?;
        println!(
            "pretrained {steps} steps ({} tokens) in {:.1}s: loss {:.3} -> {:.3}",
            stats.tokens_seen,
            sw.secs(),
            stats.losses[0],
            stats.losses.last().unwrap()
        );
        loss_curve = stats.losses.clone();
        dense.save(&ckpt)?;
    }

    // ---- 2. prune with all methods ---------------------------------------
    let calib = CalibrationSet::sample(&cfg, 4 * cfg.batch, 0xCA11B);
    let mut results: Vec<Json> = Vec::new();
    let mut models: Vec<(&str, ParamStore)> = vec![("dense", dense.clone())];

    for method in ["sparsegpt", "wanda", "besa"] {
        let mut m = dense.clone();
        let sw = Stopwatch::start();
        let pipeline = Pipeline::new(&engine, calib.batches.clone());
        match method {
            "sparsegpt" => {
                pipeline.run(&mut m, &mut SparseGptPruner { sparsity: 0.5, ..Default::default() })?
            }
            "wanda" => pipeline.run(&mut m, &mut WandaPruner { sparsity: 0.5 })?,
            _ => pipeline.run(
                &mut m,
                &mut BesaPruner::new(BesaConfig { sparsity: 0.5, ..Default::default() }),
            )?,
        };
        println!(
            "{method}: pruned to {:.4} global sparsity in {:.1}s",
            m.prunable_sparsity(cfg.n_blocks),
            sw.secs()
        );
        models.push((match method {
            "sparsegpt" => "sparsegpt",
            "wanda" => "wanda",
            _ => "besa",
        }, m));
    }

    // ---- 3. headline metric: ppl on the three domains --------------------
    println!("\n{:<10} {:>10} {:>10} {:>10} {:>10}", "method", "wiki-syn", "c4-syn", "ptb-syn", "probe-avg");
    for (name, m) in &models {
        let ppl = besa::eval::perplexity_all(&engine, m, 12, 77)?;
        let probes = besa::eval::probes::run_all(&engine, m, 30, 99)?;
        let avg = probes.last().unwrap().accuracy;
        print!("{name:<10}");
        for (_, v) in &ppl {
            print!(" {v:>10.4}");
        }
        println!(" {:>9.1}%", avg * 100.0);
        results.push(json::obj(vec![
            ("method", json::s(name)),
            (
                "ppl",
                Json::Arr(
                    ppl.iter()
                        .map(|(d, v)| json::obj(vec![("dataset", json::s(d)), ("ppl", json::num(*v))]))
                        .collect(),
                ),
            ),
            ("probe_avg", json::num(avg)),
            ("sparsity", json::num(m.prunable_sparsity(cfg.n_blocks))),
        ]));
    }

    std::fs::create_dir_all("results")?;
    let payload = json::obj(vec![
        ("config", json::s(&config)),
        ("loss_curve", Json::Arr(loss_curve.iter().map(|l| json::num(*l)).collect())),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write("results/e2e.json", payload.to_string_pretty())?;
    println!("\n[results -> results/e2e.json]");

    let (compile_s, exec_s, calls) = engine.stats();
    println!("runtime stats: {calls} artifact executions, {exec_s:.1}s exec, {compile_s:.1}s compile");
    Ok(())
}
