//! Quickstart: the public-API happy path in ~40 lines.
//!
//! Trains a small dense LM on the synthetic corpus, prunes it with BESA at
//! 50% unstructured sparsity, and compares perplexity against the dense
//! model and a Wanda baseline.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use besa::coordinator::{trainer, Pipeline};
use besa::data::batcher::CalibrationSet;
use besa::data::Domain;
use besa::model::ParamStore;
use besa::prune::besa::{BesaConfig, BesaPruner};
use besa::prune::wanda::WandaPruner;
use besa::runtime::Engine;

fn main() -> anyhow::Result<()> {
    besa::util::logging::init_from_env();
    // 1. engine: loads + compiles the AOT artifacts for the `test` config
    let engine = Engine::new(std::path::Path::new("artifacts"), "test")?;
    let cfg = engine.config().clone();

    // 2. pretrain a dense model (the stand-in for downloading a checkpoint)
    let mut dense = ParamStore::init(&cfg, 42);
    let stats = trainer::pretrain(
        &engine,
        &mut dense,
        &trainer::TrainConfig { steps: 120, lr: 3e-3, seed: 42, log_every: 40 },
    )?;
    println!(
        "dense model: {} params, loss {:.3} -> {:.3}",
        cfg.total_param_count(),
        stats.losses[0],
        stats.losses.last().unwrap()
    );

    // 3. prune with BESA (Algorithm 1) and with Wanda for comparison
    let calib = CalibrationSet::sample(&cfg, 2 * cfg.batch, 7);
    let mut besa_model = dense.clone();
    Pipeline::new(&engine, calib.batches.clone())
        .run(&mut besa_model, &mut BesaPruner::new(BesaConfig::default()))?;
    let mut wanda_model = dense.clone();
    Pipeline::new(&engine, calib.batches)
        .run(&mut wanda_model, &mut WandaPruner { sparsity: 0.5 })?;

    // 4. compare perplexity
    for (name, m) in [("dense", &dense), ("besa", &besa_model), ("wanda", &wanda_model)] {
        let ppl = besa::eval::perplexity(&engine, m, Domain::WikiSyn, 4, 77)?;
        println!(
            "{name:>6}: wiki-syn ppl {ppl:.4}  (sparsity {:.3})",
            m.prunable_sparsity(cfg.n_blocks)
        );
    }
    Ok(())
}
