//! Joint compression (paper §3.3 / Table 3): 4-bit weight quantization
//! with learnable clipping strengths optimized *jointly* with BESA's
//! sparsity allocation, vs. the quantize-then-Wanda baseline.
//!
//! ```bash
//! make artifacts && cargo run --release --example joint_compression
//! ```

use besa::coordinator::{trainer, Pipeline};
use besa::data::batcher::CalibrationSet;
use besa::model::ParamStore;
use besa::prune::besa::{BesaConfig, BesaPruner};
use besa::prune::wanda::WandaPruner;
use besa::quant::{quantize_model, QuantSpec};
use besa::runtime::Engine;

fn main() -> anyhow::Result<()> {
    besa::util::logging::init_from_env();
    let config = std::env::var("BESA_JOINT_CONFIG").unwrap_or_else(|_| "test".to_string());
    let engine = Engine::new(std::path::Path::new("artifacts"), &config)?;
    let cfg = engine.config().clone();

    // dense model: checkpoint if present, else a quick pretrain
    let ckpt = std::path::PathBuf::from(format!("runs/{config}-dense.bst"));
    let mut dense;
    if ckpt.exists() {
        dense = ParamStore::load(&cfg, &ckpt)?;
    } else {
        dense = ParamStore::init(&cfg, 5);
        trainer::pretrain(
            &engine,
            &mut dense,
            &trainer::TrainConfig { steps: 150, lr: 3e-3, seed: 5, log_every: 50 },
        )?;
    }

    let calib = CalibrationSet::sample(&cfg, 2 * cfg.batch, 0xCA11B);

    // Joint: BESA learns theta AND gamma against the block reconstruction
    let mut joint = dense.clone();
    Pipeline::new(&engine, calib.batches.clone()).run(
        &mut joint,
        &mut BesaPruner::new(BesaConfig { sparsity: 0.5, quant: true, ..Default::default() }),
    )?;

    // Baseline: quantize with fixed clipping, then Wanda-prune
    let mut jw = dense.clone();
    quantize_model(&mut jw, &cfg, QuantSpec::default())?;
    Pipeline::new(&engine, calib.batches).run(&mut jw, &mut WandaPruner { sparsity: 0.5 })?;

    println!("\n{:<14} {:>10} {:>10} {:>10} {:>9}", "variant", "wiki-syn", "c4-syn", "ptb-syn", "sparsity");
    for (name, m) in [("dense", &dense), ("joint (besa)", &joint), ("joint-wanda", &jw)] {
        let ppl = besa::eval::perplexity_all(&engine, m, 8, 77)?;
        print!("{name:<14}");
        for (_, v) in &ppl {
            print!(" {v:>10.4}");
        }
        println!(" {:>9.3}", m.prunable_sparsity(cfg.n_blocks));
    }
    println!("\nexpected shape (paper Table 3): joint (besa) < joint-wanda on every dataset");
    Ok(())
}
